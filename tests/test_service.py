"""Simulation service: canonical fingerprints, compile/result caches,
the supervised scheduler, and the socket server/client stack.

The load-bearing invariants:

* the shared fingerprint module reproduces the *exact historical bytes*
  of the sweep-journal key and the checkpoint fingerprint (frozen
  copies of the legacy implementations live here as oracles);
* every row a client receives — memoized, coalesced, fanned out, or
  computed after a worker SIGKILL — is bit-identical to calling
  ``saturation_sweep`` / ``compare_policies`` / ``run_program``
  directly;
* the point accounting is exact:
  ``memo hits + in-flight joins + computed == points total``, always.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import threading

import pytest

from repro.core.noc import fingerprint as fp
from repro.core.noc.params import NoCParams
from repro.core.noc.program import ProgramBuilder, run_program
from repro.core.noc.service import (
    CompileCache,
    PolicyCompareJob,
    ResultMemo,
    RunProgramJob,
    ServiceClient,
    ServiceError,
    SimulationServer,
    SweepJob,
    execute_workload,
    job_from_doc,
)
from repro.core.noc.service.scheduler import Scheduler
from repro.core.noc.traffic.patterns import SyntheticConfig
from repro.core.noc.traffic.sweep import (
    compare_policies,
    saturation_sweep,
)
from repro.core.topology import Mesh2D


# ---------------------------------------------------------------------------
# Satellite: canonical fingerprint module round-trips the legacy bytes.
# ---------------------------------------------------------------------------


def _legacy_journal_key(mesh, cfgs, params, engine, compile_once) -> str:
    """Frozen copy of the pre-refactor ``traffic.sweep._journal_key`` —
    the oracle proving committed journals stay resumable."""
    p = params or NoCParams()
    d = dataclasses.asdict(p)
    d.pop("faults", None)
    d["faults"] = p.faults.to_dict() if getattr(p, "faults", None) else None
    doc = {
        "mesh": [mesh.cols, mesh.rows],
        "cfgs": [dataclasses.asdict(c) for c in cfgs],
        "params": d,
        "engine": engine,
        "compile_once": bool(compile_once),
    }
    blob = json.dumps(doc, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()


def _legacy_checkpoint_canonical(payload: dict) -> bytes:
    """Frozen copy of the pre-refactor ``checkpoint._canonical``."""
    return json.dumps(payload, sort_keys=True,
                      separators=(",", ":")).encode()


def test_sweep_key_matches_legacy_bytes():
    mesh = Mesh2D(6, 4)
    cfgs = [SyntheticConfig(pattern="hotspot", rate=r, nbytes=128,
                            packets_per_node=3, seed=11, hotspot=(2, 1),
                            hotspot_frac=0.7)
            for r in (0.02, 0.05)]
    for params in (None, NoCParams(routing="oddeven", num_vcs=2)):
        for engine, once in (("heap", True), ("event", False)):
            assert fp.sweep_key(mesh, cfgs, params, engine, once) == \
                _legacy_journal_key(mesh, cfgs, params, engine, once)


def test_sweep_key_via_sweep_module_delegation():
    from repro.core.noc.traffic.sweep import _journal_key

    mesh = Mesh2D(4, 4)
    cfgs = [SyntheticConfig(pattern="uniform", rate=0.1)]
    assert _journal_key(mesh, cfgs, None, "heap", True) == \
        _legacy_journal_key(mesh, cfgs, None, "heap", True)


def test_checkpoint_fingerprint_matches_legacy_bytes():
    payload = {"format": "repro-noc-checkpoint", "version": 1, "cycle": 7,
               "mesh": [4, 4], "nested": {"b": [1, 2], "a": None}}
    assert fp.checkpoint_fingerprint(payload) == hashlib.sha256(
        _legacy_checkpoint_canonical(payload)).hexdigest()
    assert fp.canonical_json(payload, compact=True) == \
        _legacy_checkpoint_canonical(payload)


def test_checkpoint_snapshot_round_trip_still_validates():
    from repro.core.noc.netsim import NoCSim
    from repro.core.noc.resilience import Snapshot, checkpoint, restore
    from repro.core.topology import Coord

    sim = NoCSim(Mesh2D(4, 4))
    sim.add_unicast(Coord(0, 0), Coord(3, 3), 256)
    sim.run(stop_at=5)
    snap = checkpoint(sim, 5)
    again = Snapshot.from_json(snap.to_json())
    assert again.fingerprint == snap.fingerprint
    restore(again)  # must not raise


def test_journal_mismatch_names_differing_component(tmp_path):
    mesh = Mesh2D(4, 4)
    journal = str(tmp_path / "sweep.jsonl")
    saturation_sweep(mesh, "uniform", [0.05], packets_per_node=2, seed=0,
                     journal=journal)
    # Same everything but the engine: the error must say so.
    with pytest.raises(ValueError, match="different sweep configuration"):
        saturation_sweep(mesh, "uniform", [0.05], packets_per_node=2,
                         seed=0, engine="event", journal=journal)
    with pytest.raises(ValueError, match=r"differing component\(s\): engine"):
        saturation_sweep(mesh, "uniform", [0.05], packets_per_node=2,
                         seed=0, engine="event", journal=journal)
    # Different mesh AND configs: both named.
    with pytest.raises(ValueError, match="mesh.*config list"):
        saturation_sweep(Mesh2D(8, 8), "uniform", [0.07], journal=journal)


def test_journal_mismatch_without_parts_header_degrades(tmp_path):
    """Journals written before per-component digests still refuse with
    the generic message (no crash on the missing header field)."""
    mesh = Mesh2D(4, 4)
    journal = str(tmp_path / "old.jsonl")
    with open(journal, "w") as f:
        f.write(json.dumps({"kind": "repro-sweep-journal", "version": 1,
                            "key": "0" * 64}) + "\n")
    with pytest.raises(ValueError, match="predates per-component digests"):
        saturation_sweep(mesh, "uniform", [0.05], journal=journal)


def test_workload_fingerprint_on_compiled_workload():
    from repro.core.noc.program import compile_workload

    b = ProgramBuilder(Mesh2D(4, 4))
    b.unicast((0, 0), (3, 3), 1024)
    prog = b.build()
    cw = compile_workload(prog)
    assert cw.fingerprint() == fp.workload_fingerprint(prog, cw.p)
    assert cw.fingerprint("heap") != cw.fingerprint("event")


# ---------------------------------------------------------------------------
# Caches.
# ---------------------------------------------------------------------------


def test_compile_cache_lru_eviction_and_stats():
    cache = CompileCache(capacity=2)
    built = []
    for key in ("a", "b", "a", "c", "b"):
        cache.get(key, lambda k=key: built.append(k) or k.upper())
    # a,b built; a hit; c builds evicting LRU (b); b rebuilds evicting a.
    assert built == ["a", "b", "c", "b"]
    assert cache.stats.as_tuple() == (1, 4, 2)
    assert "b" in cache and "a" not in cache


def test_result_memo_eviction_order():
    memo = ResultMemo(capacity=2)
    memo.put("x", 1)
    memo.put("y", 2)
    assert memo.get("x") == 1      # refreshes x
    memo.put("z", 3)               # evicts y
    assert memo.get("y") is None
    assert memo.get("x") == 1 and memo.get("z") == 3
    assert memo.stats.evictions == 1


# ---------------------------------------------------------------------------
# Job specs and the shared execution path.
# ---------------------------------------------------------------------------


def test_job_doc_round_trip_preserves_fingerprint():
    job = SweepJob(mesh=(6, 4), pattern="hotspot", rates=(0.02, 0.05),
                   seed=3, hotspot=(2, 1), hotspot_frac=0.8,
                   params=NoCParams(routing="yx", num_vcs=2))
    again = job_from_doc(json.loads(json.dumps(job.to_doc())))
    assert again.fingerprint() == job.fingerprint()
    assert again.workloads()[0].fingerprint == job.workloads()[0].fingerprint


def test_job_validation_rejects_garbage():
    with pytest.raises(ValueError, match="unknown job kind"):
        job_from_doc({"kind": "nope"})
    with pytest.raises(ValueError, match="unknown pattern"):
        SweepJob(mesh=(4, 4), pattern="bogus", rates=(0.1,))
    with pytest.raises(ValueError, match="at least one rate"):
        SweepJob(mesh=(4, 4), pattern="uniform", rates=())


def test_execute_workload_matches_direct_sweep():
    mesh = Mesh2D(4, 4)
    rates = (0.02, 0.06, 0.1)
    direct = saturation_sweep(mesh, "transpose", rates,
                              packets_per_node=2, seed=3)
    [wl] = SweepJob(mesh=(4, 4), pattern="transpose", rates=rates,
                    packets_per_node=2, seed=3).workloads()
    rows = execute_workload(json.loads(json.dumps(wl.doc)), wl.tokens,
                            CompileCache())
    assert rows == [dataclasses.asdict(p) for p in direct]


def test_execute_workload_matches_direct_program():
    b = ProgramBuilder(Mesh2D(4, 4))
    b.unicast((0, 0), (3, 3), 4096)
    b.barrier()
    b.reduction([(0, 0), (3, 0)], (3, 3), 1024)
    prog = b.build()
    res = run_program(prog, None, mode="op")
    [wl] = RunProgramJob.of(prog, mode="op").workloads()
    [row] = execute_workload(json.loads(json.dumps(wl.doc)), wl.tokens,
                             CompileCache())
    assert row["makespan"] == res.makespan
    assert row["phase_end"] == list(res.phase_end)
    assert row["runs"] == [[r.op.id, r.inject_cycle, r.done_cycle]
                           for r in res.runs]


def test_policy_compare_row_order_matches_compare_policies():
    job = PolicyCompareJob(mesh=(4, 4), pattern="transpose",
                           rates=(0.02, 0.08), policies=("xy", "yx"),
                           vcs=(1, 2), packets_per_node=2, seed=4)
    metas = [w.meta for w in job.workloads()]
    assert metas == [{"policy": "xy", "num_vcs": 1},
                     {"policy": "xy", "num_vcs": 2},
                     {"policy": "yx", "num_vcs": 1},
                     {"policy": "yx", "num_vcs": 2}]


# ---------------------------------------------------------------------------
# Scheduler: coalescing, accounting, fairness (in-process mode: the
# behaviors under test are engine-independent of the worker pool).
# ---------------------------------------------------------------------------


def _sweep_doc(**kw):
    base = dict(mesh=(4, 4), pattern="transpose", rates=(0.03, 0.07),
                packets_per_node=2, seed=5)
    base.update(kw)
    return SweepJob(**base).to_doc()


def _collect_events():
    events = []
    lock = threading.Lock()

    def on_event(e):
        with lock:
            events.append(e)
    return events, on_event


def test_scheduler_exact_point_accounting():
    with Scheduler(workers=0) as sched:
        ev1, cb1 = _collect_events()
        sched.submit("a", _sweep_doc(), cb1)
        _wait_done(ev1)
        # Identical resubmission: all memo hits, served synchronously.
        ev2, cb2 = _collect_events()
        sched.submit("b", _sweep_doc(), cb2)
        assert ev2[-1]["event"] == "done"
        st = sched.stats()
        assert st["points"]["total"] == 4
        assert st["points"]["computed"] == 2
        assert st["points"]["memo_hits"] == 2
        assert st["points"]["inflight_joins"] == 0
        assert (st["points"]["memo_hits"] + st["points"]["inflight_joins"]
                + st["points"]["computed"]) == st["points"]["total"]
        assert st["points"]["hit_rate"] == 0.5


def _wait_done(events, timeout=120.0):
    import time

    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if any(e["event"] in ("done", "cancelled", "error")
               for e in events):
            return
        time.sleep(0.01)
    raise TimeoutError(f"no terminal event in {events}")


def test_scheduler_deterministic_error_surfaces_as_error_event():
    with Scheduler(workers=0) as sched:
        ev, cb = _collect_events()
        doc = _sweep_doc()
        doc["mesh"] = [0, 0]           # lowering will fail
        sched.submit("a", doc, cb)
        _wait_done(ev)
        terminal = [e for e in ev if e["event"] == "error"]
        assert terminal and "message" in terminal[0]
        assert sched.stats()["jobs"]["failed"] == 1


def test_scheduler_rejects_malformed_doc_without_enqueueing():
    with Scheduler(workers=0) as sched:
        with pytest.raises(ValueError):
            sched.submit("a", {"kind": "nope"}, lambda e: None)
        assert sched.stats()["jobs"]["submitted"] == 0
        assert sched.stats()["points"]["total"] == 0


# ---------------------------------------------------------------------------
# End-to-end: server + concurrent clients, bit-identity and hit rate.
# ---------------------------------------------------------------------------


GRID = dict(mesh=(4, 4), pattern="transpose",
            rates=[0.02, 0.04, 0.06, 0.08, 0.1, 0.12],
            packets_per_node=2, seed=7)


@pytest.mark.parametrize("transport", ["unix", "tcp"])
def test_three_concurrent_clients_bit_identical_and_hit_rate(transport):
    direct = saturation_sweep(Mesh2D(4, 4), "transpose", GRID["rates"],
                              packets_per_node=2, seed=7)
    server_kw = (dict(tcp=("127.0.0.1", 0), token="s3cret")
                 if transport == "tcp" else {})
    with SimulationServer(workers=2, chunk_tokens=3, **server_kw) as srv:
        addr = srv.path if transport == "unix" else srv.tcp_address
        client_kw = {} if transport == "unix" else {"token": "s3cret"}
        results: dict[str, list] = {}
        errors: list = []

        def run(name):
            try:
                with ServiceClient(addr, **client_kw) as cli:
                    results[name] = cli.submit_sweep(**GRID).sweep_points()
            except Exception as exc:  # noqa: BLE001
                errors.append((name, exc))

        threads = [threading.Thread(target=run, args=(f"c{i}",))
                   for i in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert not errors, errors
        assert len(results) == 3
        for name, pts in results.items():
            assert pts == direct, f"client {name} rows differ from direct"

        with ServiceClient(srv.path) as cli:
            st = cli.stats()
    pts_st = st["points"]
    assert pts_st["total"] == 18
    assert pts_st["computed"] == 6           # one client's worth, once
    assert (pts_st["memo_hits"] + pts_st["inflight_joins"]) == 12
    assert pts_st["hit_rate"] > 0.5          # 12/18 by construction
    assert (pts_st["memo_hits"] + pts_st["inflight_joins"]
            + pts_st["computed"]) == pts_st["total"]


def test_streamed_rows_arrive_before_done_and_reassemble():
    with SimulationServer(workers=2, chunk_tokens=1) as srv:
        with ServiceClient(srv.path) as cli:
            h = cli.submit_sweep(**GRID)
            seen = list(h.iter_rows())
            assert sorted(k for k, _ in seen) == list(range(6))
            direct = saturation_sweep(Mesh2D(4, 4), "transpose",
                                      GRID["rates"], packets_per_node=2,
                                      seed=7)
            assert h.sweep_points() == direct


def test_policy_compare_over_wire_matches_direct():
    kw = dict(pattern="transpose", rates=[0.02, 0.08],
              policies=("xy", "yx"), vcs=(1,), packets_per_node=2, seed=4)
    direct = compare_policies(Mesh2D(4, 4), **kw)
    with SimulationServer(workers=2) as srv:
        with ServiceClient(srv.path) as cli:
            rows = cli.submit_policy_compare(mesh=(4, 4), **kw).policy_sweeps()
    assert rows == direct


def test_program_job_over_wire_matches_direct():
    b = ProgramBuilder(Mesh2D(4, 4))
    b.unicast((0, 0), (3, 3), 4096)
    b.barrier()
    b.reduction([(0, 0), (3, 0)], (3, 3), 1024)
    prog = b.build()
    res = run_program(prog, None, mode="op")
    with SimulationServer(workers=0) as srv:
        with ServiceClient(srv.path) as cli:
            row = cli.submit_program(prog, mode="op").result()
    assert row["makespan"] == res.makespan
    assert row["runs"] == [[r.op.id, r.inject_cycle, r.done_cycle]
                           for r in res.runs]


def test_sigkilled_worker_chunk_retried_no_dup_no_missing():
    direct = saturation_sweep(Mesh2D(4, 4), "uniform",
                              [0.02, 0.04, 0.06, 0.08],
                              packets_per_node=2, seed=9)
    with SimulationServer(workers=2, chunk_tokens=2) as srv:
        srv.scheduler.chaos_kill_after = 1    # SIGKILL holder of chunk #1
        with ServiceClient(srv.path) as cli:
            h = cli.submit_sweep(mesh=(4, 4), pattern="uniform",
                                 rates=[0.02, 0.04, 0.06, 0.08],
                                 packets_per_node=2, seed=9)
            pts = h.sweep_points()
            st = cli.stats()
    assert pts == direct
    assert st["worker_respawns"] >= 1
    assert st["chunk_retries"] >= 1
    # No duplicate computation of non-killed points, none missing:
    assert st["points"]["computed"] == 4
    assert st["points"]["total"] == 4


def test_cancellation_frees_queued_points_and_slots():
    with SimulationServer(workers=1, chunk_tokens=1) as srv:
        with ServiceClient(srv.path) as a, ServiceClient(srv.path) as b:
            big = a.submit_sweep(mesh=(8, 8), pattern="uniform",
                                 rates=[0.01 + 0.005 * i for i in range(12)],
                                 seed=1)
            assert big.rows_total == 12
            big.cancel()
            assert big.wait(timeout=60) == "cancelled"
            with pytest.raises(ServiceError, match="cancelled"):
                big.collect()
            # The slot is free for the next client immediately.
            small = b.submit_sweep(mesh=(4, 4), pattern="transpose",
                                   rates=[0.05], packets_per_node=2, seed=2)
            assert small.wait(timeout=120) == "done"
            st = b.stats()
    assert st["jobs"]["cancelled"] == 1
    assert st["jobs"]["done"] == 1
    assert st["queue_depth"] == 0
    # Dropped never-computed points are refunded from the accounting.
    pts = st["points"]
    assert (pts["memo_hits"] + pts["inflight_joins"]
            + pts["computed"]) == pts["total"]


def test_client_disconnect_cancels_its_jobs():
    with SimulationServer(workers=1, chunk_tokens=1) as srv:
        cli = ServiceClient(srv.path)
        h = cli.submit_sweep(mesh=(8, 8), pattern="uniform",
                             rates=[0.01 + 0.005 * i for i in range(10)],
                             seed=6)
        assert h.rows_total == 10
        cli.close()                       # vanish mid-job
        import time

        t0 = time.monotonic()
        while time.monotonic() - t0 < 60:
            st = srv.scheduler.stats()
            if (st["jobs"]["cancelled"] >= 1 and st["queue_depth"] == 0
                    and st["slots_busy"] == 0):
                break
            time.sleep(0.05)
        st = srv.scheduler.stats()
        assert st["jobs"]["cancelled"] == 1
        assert st["queue_depth"] == 0


def test_in_process_degraded_mode_bit_identical():
    direct = saturation_sweep(Mesh2D(4, 4), "transpose", [0.03, 0.06],
                              packets_per_node=2, seed=5)
    with SimulationServer(workers=0) as srv:
        with ServiceClient(srv.path) as cli:
            pts = cli.submit_sweep(mesh=(4, 4), pattern="transpose",
                                   rates=[0.03, 0.06], packets_per_node=2,
                                   seed=5).sweep_points()
            st = cli.stats()
    assert pts == direct
    assert st["degraded"]


def test_service_telemetry_spans_and_counters():
    from repro.core.noc.telemetry import Collector
    from repro.core.noc.telemetry.perfetto import trace_events

    col = Collector()
    with SimulationServer(workers=0, telemetry=col) as srv:
        with ServiceClient(srv.path) as cli:
            cli.submit_sweep(mesh=(4, 4), pattern="transpose",
                             rates=[0.05], packets_per_node=2,
                             seed=5).sweep_points()
    ev = trace_events(col)
    assert any(e.get("ph") == "X" and e["name"].startswith("job:")
               for e in ev)
    names = {e["name"] for e in ev if e.get("ph") == "C"}
    assert {"service.queue_depth", "service.slots_busy",
            "service.cache_hit_rate"} <= names
    ts = [e["ts"] for e in ev if e["ph"] != "M"]
    assert ts == sorted(ts)
    # counter_samples stays out of checkpoint state: byte stability.
    assert "counter_samples" not in col.state_dict()
