"""Routing-policy / virtual-channel saturation shoot-out.

Sweeps the two adversarial synthetic patterns (hotspot, transpose)
across the routing policies (XY / O1TURN / odd-even) and VC counts
(1 / 2 / 4, packet-sliced) on 8x8 and 16x16 meshes, plus the
mixed-class collective storm that isolates the head-of-line blocking
VCs remove.  Emits ``BENCH_routing.json`` at the repo root with the
saturation point of every configuration, its latency curves (mean and
p50/p95/p99 tails — the knee shows in the tail before the mean moves)
and the shift relative to XY — the trajectory to regress
adaptive-routing work against.

Run standalone as a CI gate::

    PYTHONPATH=src python -m benchmarks.bench_routing --smoke

exits non-zero if O1TURN saturates no later than XY on the 8x8
transpose sweep, or if the mixed-class storm fails to complete strictly
earlier with 2 VCs than with 1.

Rate grids are per (pattern, mesh): the hotspot knee scales inversely
with tile count (all hotspot traffic funnels into at most two links at
the hotspot), while transpose is bisection-limited; each grid starts
with a genuinely idle rate so the knee detector has a zero-load anchor.
"""

from __future__ import annotations

import json
import math
import os
import time
from pathlib import Path

from repro.core.noc.params import PAPER_MICRO
from repro.core.noc.traffic import (
    compare_policies,
    mixed_storm,
    replay,
    saturation_shifts,
)
from repro.core.topology import Mesh2D

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_routing.json"

POLICIES = ("xy", "o1turn", "oddeven")
VCS = (1, 2, 4)

# (pattern, mesh side) -> (rates, packets_per_node, pattern kwargs)
SWEEPS = {
    ("hotspot", 8): ((0.004, 0.008, 0.013, 0.02, 0.03, 0.045), 8,
                     {"hotspot_frac": 0.5}),
    ("hotspot", 16): ((0.001, 0.002, 0.003, 0.0045, 0.007, 0.01, 0.015), 8,
                      {"hotspot_frac": 0.5}),
    ("transpose", 8): ((0.02, 0.08, 0.15, 0.25, 0.4, 0.6), 24, {}),
    ("transpose", 16): ((0.02, 0.05, 0.1, 0.18, 0.3, 0.45), 16, {}),
}

MIXED_MESHES = (8, 16)


def _workers() -> int:
    return min(4, os.cpu_count() or 1)


def _jsonable(sat: float):
    # JSON has no Infinity literal; "inf" marks "did not saturate in the
    # swept range", which for saturation points is strictly *better* than
    # any finite rate (and distinct from saturating at the last rate).
    return "inf" if math.isinf(sat) else sat


def _sweep_record(pattern: str, side: int, policies=POLICIES, vcs=VCS) -> dict:
    rates, ppn, kw = SWEEPS[(pattern, side)]
    res = compare_policies(
        Mesh2D(side, side), pattern, rates, policies=policies, vcs=vcs,
        packets_per_node=ppn, params=PAPER_MICRO, workers=_workers(), **kw,
    )
    shifts = saturation_shifts(res)
    return {
        "rates": list(rates),
        "packets_per_node": ppn,
        "rows": [
            {
                "policy": r.policy,
                "num_vcs": r.num_vcs,
                "saturation": _jsonable(r.saturation),
                "mean_latency": [round(p.mean_latency, 1) for p in r.points],
                "p50_latency": [round(p.p50_latency, 1) for p in r.points],
                "p95_latency": [round(p.p95_latency, 1) for p in r.points],
                "p99_latency": [round(p.p99_latency, 1) for p in r.points],
                "throughput": [round(p.throughput, 4) for p in r.points],
                "shift_vs_xy": _jsonable(shifts[(r.policy, r.num_vcs)]),
            }
            for r in res
        ],
    }


def _mixed_record(side: int) -> dict:
    trace = mixed_storm(
        Mesh2D(side, side), tile_bytes=4096, unicasts_per_node=4,
        rate=1.0, phases=2,
    )
    makespans = {}
    for v in VCS:
        r = replay(trace, params=PAPER_MICRO, num_vcs=v)
        makespans[str(v)] = r.makespan
    return makespans


def _row_sat(rec: dict, policy: str, num_vcs: int = 1) -> float:
    for row in rec["rows"]:
        if row["policy"] == policy and row["num_vcs"] == num_vcs:
            return math.inf if row["saturation"] == "inf" else row["saturation"]
    raise KeyError((policy, num_vcs))


def _hot_links_record(side: int = 16, rate: float = 0.18, k: int = 8) -> dict:
    """Per-policy hot-link tables on a loaded transpose population — the
    *where* behind the saturation shifts: XY funnels the bisection onto a
    few row/column channels (high peak utilization), O1TURN's pid-keyed
    split and odd-even's adaptivity spread the same traffic across more
    links (lower peak, more even top-k)."""
    from repro.core.noc.telemetry import Collector
    from repro.core.noc.traffic import SyntheticConfig, synthetic_trace

    mesh = Mesh2D(side, side)
    trace = synthetic_trace(mesh, SyntheticConfig(
        pattern="transpose", rate=rate, nbytes=256, packets_per_node=8,
        seed=0,
    ))
    out: dict = {"pattern": "transpose", "mesh": f"{side}x{side}",
                 "rate": rate, "policies": {}}
    for policy in POLICIES:
        col = Collector()
        res = replay(trace, params=PAPER_MICRO, routing=policy,
                     num_vcs=2, telemetry=col)
        stats = col.stats()
        table = stats.link_table(k)
        out["policies"][policy] = {
            "makespan": res.makespan,
            "total_busy_beats": stats.total_busy_beats(),
            "peak_link_utilization": table[0]["utilization"] if table else 0.0,
            "hot_links": table,
        }
    return out


def rows():
    results: dict = {"sweeps": {}, "mixed_storm": {}}
    out = []
    for (pattern, side), _ in SWEEPS.items():
        t0 = time.perf_counter()
        rec = _sweep_record(pattern, side)
        wall = time.perf_counter() - t0
        results["sweeps"][f"{pattern}_{side}x{side}"] = rec
        for row in rec["rows"]:
            out.append((
                f"{pattern}{side}/{row['policy']}/vc{row['num_vcs']}",
                wall * 1e6 / len(rec["rows"]),
                f"sat={row['saturation']};shift_vs_xy={row['shift_vs_xy']}",
            ))
    for side in MIXED_MESHES:
        makespans = _mixed_record(side)
        results["mixed_storm"][f"{side}x{side}"] = makespans
        improve = makespans["1"] / makespans["2"]
        out.append((
            f"mixed{side}/vcs", 0.0,
            ";".join(f"vc{v}={m}" for v, m in makespans.items())
            + f";x_vc2_over_vc1={improve:.2f}",
        ))
    # The two headline properties BENCH_routing.json exists to track:
    hot16 = results["sweeps"]["hotspot_16x16"]
    results["claims"] = {
        "o1turn_hotspot16_saturates_after_xy":
            _row_sat(hot16, "o1turn") > _row_sat(hot16, "xy"),
        "mixed_storm_2vc_beats_1vc": {
            k: v["2"] < v["1"] for k, v in results["mixed_storm"].items()
        },
    }
    hl = _hot_links_record()
    results["hot_links"] = hl
    peaks = {p: r["peak_link_utilization"]
             for p, r in hl["policies"].items()}
    out.append((
        "hot_links/transpose16", 0.0,
        ";".join(f"{p}_peak={peaks[p]}" for p in POLICIES),
    ))
    from benchmarks.run import provenance

    results["provenance"] = provenance()
    JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")
    return out


def smoke() -> int:
    """CI gate: routing diversity and VC isolation must actually pay.

    * O1TURN must saturate strictly later than XY on the 8x8 transpose
      sweep (adaptive-routing scenario family).
    * The 8x8 mixed-class storm must complete strictly earlier with 2
      VCs than with 1 (head-of-line blocking scenario family).
    """
    rec = _sweep_record("transpose", 8, policies=("xy", "o1turn"), vcs=(1,))
    sat_xy = _row_sat(rec, "xy")
    sat_o1 = _row_sat(rec, "o1turn")
    print(f"transpose8 saturation: xy={sat_xy} o1turn={sat_o1}")
    if not sat_o1 > sat_xy:
        print("FAIL: O1TURN saturates no later than XY on the transpose sweep")
        return 1
    makespans = _mixed_record(8)
    print(f"mixed8 makespans: {makespans}")
    if not makespans["2"] < makespans["1"]:
        print("FAIL: 2 VCs do not beat 1 VC on the mixed-class storm")
        return 1
    print("OK: o1turn outlasts xy; 2 VCs strictly beat 1 on the mixed storm")
    return 0


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        sys.exit(smoke())
    for name, us, derived in rows():
        print(f"{name},{us},{derived}")
