"""Decoder-only transformer LM covering the dense / MoE / local-global archs.

Layers are scanned (stacked parameters with a leading L dimension) so the
compiled HLO is O(1) in depth.  Per-layer attention windows are passed as a
scanned integer array, which lets gemma3's 5:1 local:global pattern share
one homogeneous scan body (window == 0 means full attention).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models.attention import KVCache
from repro.models.common import (
    ModelConfig,
    REPLICATED,
    ShardingPolicy,
    chunked_cross_entropy,
    constrain,
    dense_init,
    embed_init,
    maybe_remat,
    rms_norm,
)


def layer_windows_list(cfg: ModelConfig) -> list[int]:
    """Per-layer attention window (0 = full causal), as static ints."""
    L = cfg.n_layers
    if cfg.local_global_ratio > 0:
        r = cfg.local_global_ratio
        return [0 if (i + 1) % (r + 1) == 0 else cfg.attn_window for i in range(L)]
    if cfg.attn_window > 0:
        return [cfg.attn_window] * L
    return [0] * L


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    return jnp.asarray(layer_windows_list(cfg), jnp.int32)


def _layer_at(layers, i: int):
    return jax.tree.map(lambda a: a[i], layers)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init(rng, cfg: ModelConfig):
    k_embed, k_layers, k_head = jax.random.split(rng, 3)

    def layer_init(key):
        ka, km = jax.random.split(key)
        p = {
            "norm1": jnp.zeros((cfg.d_model,), cfg.param_dtype),
            "norm2": jnp.zeros((cfg.d_model,), cfg.param_dtype),
            "attn": attn_mod.init_attn_params(ka, cfg),
        }
        if cfg.n_experts:
            p["moe"] = mlp_mod.init_moe_params(km, cfg)
        else:
            p["mlp"] = mlp_mod.init_mlp_params(km, cfg)
        return p

    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(layer_init)(layer_keys)
    params = {
        "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model, cfg.param_dtype),
        "layers": layers,
        "final_norm": jnp.zeros((cfg.d_model,), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(k_head, cfg.padded_vocab, cfg.d_model, cfg.param_dtype)
    return params


def param_specs(cfg: ModelConfig, policy: ShardingPolicy):
    def stack(spec: P) -> P:
        return P(None, *spec)

    layer = {
        "norm1": P(None),
        "norm2": P(None),
        "attn": jax.tree.map(stack, attn_mod.attn_param_specs(cfg, policy),
                             is_leaf=lambda x: isinstance(x, P)),
    }
    if cfg.n_experts:
        layer["moe"] = jax.tree.map(stack, mlp_mod.moe_param_specs(cfg, policy),
                                    is_leaf=lambda x: isinstance(x, P))
    else:
        layer["mlp"] = jax.tree.map(stack, mlp_mod.mlp_param_specs(cfg, policy),
                                    is_leaf=lambda x: isinstance(x, P))
    layer = {
        "norm1": P(None, None),
        "norm2": P(None, None),
        **{k: v for k, v in layer.items() if k in ("attn", "moe", "mlp")},
    }
    specs = {
        "embed": policy.embed(cfg.padded_vocab),
        "layers": layer,
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = policy.embed(cfg.padded_vocab)
    return specs


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _layer_fwd(layer_params, x, positions, window, cfg: ModelConfig,
               policy: ShardingPolicy):
    h = rms_norm(x, layer_params["norm1"], cfg.norm_eps)
    h = attn_mod.attention(layer_params["attn"], h, positions, cfg,
                           window=window, policy=policy)
    x = x + h
    h = rms_norm(x, layer_params["norm2"], cfg.norm_eps)
    if cfg.n_experts:
        h, aux = mlp_mod.moe(layer_params["moe"], h, cfg, policy)
    else:
        h, aux = mlp_mod.mlp(layer_params["mlp"], h, cfg, policy), 0.0
    return x + h, aux


def forward(params, tokens, cfg: ModelConfig, policy: ShardingPolicy = REPLICATED):
    """tokens: (B, S) -> hidden (B, S, d), aux_loss."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    x = constrain(x, policy.act_bsd())
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    windows = layer_windows(cfg)

    def body(carry, xs):
        x, aux = carry
        layer_params, window = xs
        x, a = _layer_fwd(layer_params, x, positions, window, cfg, policy)
        return (x, aux + a), None

    body = maybe_remat(body, cfg.remat)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros(())),
                                   (params["layers"], windows))
    else:
        aux = jnp.zeros(())
        for i, w in enumerate(layer_windows_list(cfg)):
            (x, aux), _ = body((x, aux), (_layer_at(params["layers"], i),
                                          jnp.asarray(w, jnp.int32)))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def loss_fn(params, batch, cfg: ModelConfig, policy: ShardingPolicy = REPLICATED):
    hidden, aux = forward(params, batch["tokens"], cfg, policy)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    loss = chunked_cross_entropy(hidden, head, batch["labels"], cfg, policy)
    return loss + 0.01 * aux


# -- serving ----------------------------------------------------------------


def prefill(params, tokens, cfg: ModelConfig, policy: ShardingPolicy = REPLICATED,
            max_len: int | None = None):
    """Full-sequence prefill; returns (last-token logits, KV cache)."""
    B, S = tokens.shape
    max_len = max_len or S
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    x = constrain(x, policy.act_bsd())
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    windows = layer_windows(cfg)

    def body(x, xs):
        layer_params, window = xs
        h = rms_norm(x, layer_params["norm1"], cfg.norm_eps)
        # re-compute q/k/v so we can emit the cache entries
        q, k, v = attn_mod._qkv(layer_params["attn"], h, cfg)
        from repro.models.rope import apply_rope

        qr = apply_rope(q, positions, cfg.rope_theta)
        kr = apply_rope(k, positions, cfg.rope_theta)
        mask = attn_mod.causal_window_mask(S, S, window)
        o = attn_mod._sdpa(qr, kr, v, mask, cfg)
        o = o @ layer_params["attn"]["wo"].astype(cfg.compute_dtype)
        x = x + constrain(o, policy.act_bsd())
        h = rms_norm(x, layer_params["norm2"], cfg.norm_eps)
        if cfg.n_experts:
            h, _ = mlp_mod.moe(layer_params["moe"], h, cfg, policy)
        else:
            h = mlp_mod.mlp(layer_params["mlp"], h, cfg, policy)
        x = x + h
        pad = max_len - S
        kc = jnp.pad(kr, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, (kc, vc)

    body = maybe_remat(body, cfg.remat)
    if cfg.scan_layers:
        x, (k_all, v_all) = jax.lax.scan(body, x, (params["layers"], windows))
    else:
        ks, vs = [], []
        for i, w in enumerate(layer_windows_list(cfg)):
            x, (kc, vc) = body(x, (_layer_at(params["layers"], i),
                                   jnp.asarray(w, jnp.int32)))
            ks.append(kc)
            vs.append(vc)
        k_all, v_all = jnp.stack(ks), jnp.stack(vs)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = x[:, -1].astype(jnp.float32) @ head.astype(jnp.float32).T
    return logits, KVCache(k=k_all, v=v_all)


def decode_step(params, cache: KVCache, tokens, pos, cfg: ModelConfig,
                policy: ShardingPolicy = REPLICATED):
    """One decode step. tokens: (B, 1); pos: scalar int32 (current position)."""
    B = tokens.shape[0]
    x = params["embed"][tokens].astype(cfg.compute_dtype)
    windows = layer_windows(cfg)

    def body(x, xs):
        layer_params, window, k_l, v_l = xs
        h = rms_norm(x, layer_params["norm1"], cfg.norm_eps)
        o, new_cache = attn_mod.attention_decode(
            layer_params["attn"], h, KVCache(k_l, v_l), pos, cfg,
            window=window, policy=policy)
        x = x + o
        h = rms_norm(x, layer_params["norm2"], cfg.norm_eps)
        if cfg.n_experts:
            h, _ = mlp_mod.moe(layer_params["moe"], h, cfg, policy)
        else:
            h = mlp_mod.mlp(layer_params["mlp"], h, cfg, policy)
        return x + h, (new_cache.k, new_cache.v)

    if cfg.scan_layers:
        x, (k_all, v_all) = jax.lax.scan(body, x, (params["layers"], windows,
                                                   cache.k, cache.v))
    else:
        ks, vs = [], []
        for i, w in enumerate(layer_windows_list(cfg)):
            x, (kc, vc) = body(x, (_layer_at(params["layers"], i),
                                   jnp.asarray(w, jnp.int32),
                                   cache.k[i], cache.v[i]))
            ks.append(kc)
            vs.append(vc)
        k_all, v_all = jnp.stack(ks), jnp.stack(vs)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = x[:, -1].astype(jnp.float32) @ head.astype(jnp.float32).T
    return logits, KVCache(k=k_all, v=v_all)
