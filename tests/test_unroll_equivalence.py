"""scan_layers=False (dry-run lowering mode) must match the scanned path."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import get_family

ARCHS = ["qwen1_5_0_5b", "gemma3_12b", "rwkv6_3b", "whisper_base", "phi3_5_moe"]


@pytest.mark.parametrize("arch", ARCHS)
def test_unrolled_matches_scanned(arch):
    cfg = get_smoke_config(arch)
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "whisper":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (2, cfg.encoder_len, cfg.d_model)) * 0.1

    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    l_scan = jax.jit(lambda p: fam.loss_fn(p, batch, cfg))(params)
    l_unroll = jax.jit(lambda p: fam.loss_fn(p, batch, cfg_u))(params)
    np.testing.assert_allclose(float(l_scan), float(l_unroll), rtol=1e-5)
