"""Mid-run fault arrival: a seedable timeline of ``(cycle, FaultSet)``
events applied at checkpoint boundaries.

PR 6's fault subsystem resolves faults at *stream construction* time —
detoured routes, re-grafted trees, flaky rate penalties — which models a
fabric that is broken before the workload starts.  This module models
faults that arrive *during* the run without touching any engine's inner
loop:

    run to the event cycle (``stop_at`` pause) -> optionally checkpoint
    -> compose the event's faults into the active set -> re-lower the
    surviving affected traffic through the same detour/re-graft/escape-VC
    machinery -> resume (``start_cycle``).

Because the pause is an exact cycle boundary and re-lowering reuses the
static fault path, the per-VC CDG deadlock gate re-runs on the composed
fault set before the resumed segment simulates (``NoCSim.run`` re-checks
whenever new route dependencies were added), and an **empty timeline is
bit-identical to a plain ``sim.run()``** — the segment loop never
executes and nothing is touched.

Re-lowering semantics (deterministic by construction):

* Only *live* streams whose route touches a newly-dead or newly-flaky
  link — or whose required endpoints died — are affected; everything
  else keeps its arrival lists and frontier untouched.
* An affected stream is re-lowered from its provenance
  (``_StreamState.origin``) for its **remaining** traffic: delivered
  beats = the minimum final-edge arrival count, remainder re-lowered as
  ``remaining * beat_bytes`` bytes through the composed fault set.  The
  new stream replaces the old **in place** (same stream index), so
  round-robin arbitration positions are preserved for every other
  stream.  Its injection re-arms at the event cycle (fresh DMA setup
  ``alpha``); a stream still waiting on unreleased gates keeps its gates
  and re-arms relative to their release, like a fresh lowering would.
* Drop rules mirror ``faults.model.degrade_program``: a unicast with a
  dead endpoint, a multicast with a dead source or all destinations
  dead, a reduction with a dead root or all sources dead, and a timed
  stream on a dead tile are *tombstoned* — ``done_cycle`` set to the
  event cycle, so gated successors release the cycle after (partial
  delivery stands; the op is abandoned, not retried).
* Hand-built streams (``origin is None``) cannot be re-lowered; a fault
  event that touches one raises.

``EngineProfile`` reports ``fault_events`` / ``relowered_streams`` /
``dropped_streams`` for runs driven through :func:`run_with_timeline`.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Optional, Sequence

from repro.core.noc.faults.model import FaultSet
from repro.core.noc.faults.repair import escape_vc as _escape_vc_of
from repro.core.topology import Mesh2D


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """``faults`` arrive (are added to the active set) at ``cycle``."""

    cycle: int
    faults: FaultSet

    def __post_init__(self):
        if self.cycle < 0:
            raise ValueError(f"fault event cycle must be >= 0, got {self.cycle}")

    def to_dict(self) -> dict:
        return {"cycle": self.cycle, "faults": self.faults.to_dict()}

    @staticmethod
    def from_dict(d: dict) -> "FaultEvent":
        return FaultEvent(int(d["cycle"]), FaultSet.from_dict(d["faults"]))


class FaultTimeline:
    """Normalized sequence of fault events: sorted by cycle, same-cycle
    events merged by :meth:`FaultSet.union`, empty fault sets dropped."""

    __slots__ = ("events",)

    def __init__(self, events: Sequence[FaultEvent] = ()):
        merged: dict[int, FaultSet] = {}
        for ev in events:
            if ev.faults.empty:
                continue
            cur = merged.get(ev.cycle)
            merged[ev.cycle] = (
                ev.faults if cur is None else cur.union(ev.faults))
        self.events: tuple[FaultEvent, ...] = tuple(
            FaultEvent(c, fs) for c, fs in sorted(merged.items()))

    @property
    def empty(self) -> bool:
        return not self.events

    def __iter__(self):
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def __eq__(self, other) -> bool:
        return (isinstance(other, FaultTimeline)
                and self.events == other.events)

    def __repr__(self) -> str:
        return f"FaultTimeline({list(self.events)!r})"

    def to_dict(self) -> dict:
        return {"events": [ev.to_dict() for ev in self.events]}

    @staticmethod
    def from_dict(d: dict) -> "FaultTimeline":
        return FaultTimeline(
            [FaultEvent.from_dict(e) for e in d.get("events", ())])

    @staticmethod
    def sample(
        mesh: Mesh2D,
        events: int = 1,
        seed: int = 0,
        cycle_window: tuple[int, int] = (50, 500),
        dead_links: int = 1,
        dead_routers: int = 0,
        flaky_links: int = 0,
        keep_connected: bool = True,
    ) -> "FaultTimeline":
        """Seeded random timeline: ``events`` fault arrivals at cycles
        drawn from ``cycle_window``, each a ``FaultSet.sample`` with the
        requested element counts (connectivity-preserving by default)."""
        rng = random.Random(seed)
        out = []
        for _ in range(events):
            cycle = rng.randrange(cycle_window[0], max(cycle_window[1],
                                                       cycle_window[0] + 1))
            fs = FaultSet.sample(
                mesh, dead_links=dead_links, dead_routers=dead_routers,
                flaky_links=flaky_links, seed=rng.randrange(1 << 31),
                keep_connected=keep_connected,
            )
            out.append(FaultEvent(cycle, fs))
        return FaultTimeline(out)


# -- event application -------------------------------------------------------


def _link_edges(st) -> list:
    """Physical link edges of a stream (self-edges model local
    inject/eject and never traverse the fabric)."""
    return [e for e in st.edges() if e[0] != e[1]]


def _affected(st, old: Optional[FaultSet], new: FaultSet) -> bool:
    """True when ``new`` changes the fault status of any link this stream
    crosses relative to ``old`` (newly dead, or newly/differently flaky)."""
    for a, b in _link_edges(st):
        if new.link_is_dead(a, b):
            if old is None or not old.link_is_dead(a, b):
                return True
            continue
        nf = new.flaky_of(a, b)
        of = old.flaky_of(a, b) if old is not None else None
        if nf != of:
            return True
    return False


def _drop_verdict(origin: tuple, faults: FaultSet, mesh: Mesh2D) -> bool:
    """Mirror of ``degrade_program``'s drop rules, keyed on provenance."""
    kind = origin[0]
    dead = faults.router_is_dead
    if kind == "unicast":
        _, src, dst, _n = origin
        return dead(src) or dead(dst)
    if kind == "multicast":
        _, src, maddr, _n = origin
        if dead(src):
            return True
        return all(dead(d) for d in maddr.destinations(mesh))
    if kind == "reduction":
        _, sources, dst, _n, _ia, _tc = origin
        if dead(dst):
            return True
        return all(dead(s) for s in sources)
    if kind == "timed":
        _, at, _cycles = origin
        return dead(at)
    raise ValueError(f"unknown stream origin kind {kind!r}")


def _relower(sim, idx: int, st, tf: int) -> None:
    """Replace live stream ``idx`` in place with its remaining traffic
    lowered through the (already composed) ``sim.faults``."""
    origin = st.origin
    kind = origin[0]
    delivered = min(
        (len(st.arrivals.get(e, ())) for e in st.finals), default=0)
    remaining = st.n_beats - delivered
    if remaining <= 0:  # pragma: no cover - a drained stream is done
        return
    nbytes = remaining * sim.p.beat_bytes
    if kind == "unicast":
        _, src, dst, _n = origin
        spec = sim.unicast_spec(src, dst, nbytes)
    elif kind == "multicast":
        _, src, maddr, _n = origin
        spec = sim.multicast_spec(src, maddr, nbytes)
    elif kind == "reduction":
        _, sources, dst, _n, inject_alpha, traffic_class = origin
        spec = sim.reduction_spec(
            sources, dst, nbytes,
            inject_alpha=inject_alpha, traffic_class=traffic_class)
    else:  # timed streams never cross links; they are dropped or kept
        raise ValueError(f"cannot re-lower stream of kind {kind!r}")
    # Gated-and-unreleased streams have delivered nothing; re-arm relative
    # to the eventual gate release (start=0), exactly like a fresh
    # lowering.  Everything else re-arms its DMA at the event cycle.
    pending_gates = bool(st.gates) and st._t0() is None
    new = spec.instantiate(sim, 0.0 if pending_gates else float(tf))
    sim.streams.pop()  # instantiate appended it; it replaces idx instead
    new.gates = list(st.gates)
    sim.streams[idx] = new


def apply_fault_event(sim, ev: FaultEvent) -> dict:
    """Fold one fault arrival into a sim paused at ``ev.cycle``: compose
    the fault sets, install the composed set (escape VC included),
    tombstone doomed streams and re-lower the affected survivors.

    Returns ``{"relowered": n, "dropped": n}``.  The sim counters the
    next ``run(profile=True)`` reports are updated too, and any new route
    dependencies mark the CDG dirty so the resumed run re-verifies
    deadlock freedom on the composed fault set before simulating.
    """
    old = sim.faults
    composed = old.union(ev.faults) if old is not None else ev.faults
    composed.validate_for(sim.mesh)
    tf = ev.cycle
    sim.p = dataclasses.replace(sim.p, faults=composed)
    sim.faults = sim.p.faults
    if sim.faults is not None:
        sim._escape_vc = _escape_vc_of(
            sim.p.routing, sim.mesh, sim.p.num_vcs)
    fc = sim._fault_counts
    fc["fault_events"] = fc.get("fault_events", 0) + 1
    replaced: dict[int, object] = {}
    n_drop = n_relower = 0
    for idx, st in enumerate(sim.streams):
        if st.done_cycle is not None:
            continue
        if st.origin is None:
            if _affected(st, old, composed):
                raise RuntimeError(
                    f"fault event at cycle {tf} hits hand-built stream "
                    f"#{idx} (no lowering provenance); only builder-made "
                    "streams can be re-lowered mid-run")
            continue
        if _drop_verdict(st.origin, composed, sim.mesh):
            st.done_cycle = tf
            st.ready_hint = None
            n_drop += 1
            continue
        if st.origin[0] == "timed" or not _affected(st, old, composed):
            continue
        _relower(sim, idx, st, tf)
        replaced[id(st)] = sim.streams[idx]
        n_relower += 1
    fc["dropped_streams"] = fc.get("dropped_streams", 0) + n_drop
    fc["relowered_streams"] = fc.get("relowered_streams", 0) + n_relower
    # Rewire gate references onto the replacement streams and drop the
    # cached gate origins / readiness hints of every live stream — a gate
    # may have been tombstoned or replaced outside any engine's view.
    for st in sim.streams:
        if st.done_cycle is not None:
            continue
        if any(id(g) in replaced for g in st.gates):
            st.gates = [replaced.get(id(g), g) for g in st.gates]
        st._gate_t0 = None
        st.ready_hint = None
    tel = getattr(sim, "telemetry", None)
    if tel is not None:
        tel.annotate(
            tf, "fault_event",
            f"{ev.faults.describe()}; relowered={n_relower}, "
            f"dropped={n_drop}")
    return {"relowered": n_relower, "dropped": n_drop}


def run_with_timeline(
    sim,
    timeline: Optional[FaultTimeline],
    max_cycles: int = 2_000_000,
    engine: str = "heap",
    profile: bool = False,
    checkpoint_events: bool = False,
):
    """Run ``sim`` to completion, applying ``timeline``'s fault events at
    their cycles.  An empty (or None) timeline is exactly ``sim.run()`` —
    bit-identical, no segmenting.

    The return convention matches ``sim.run``: the makespan, or the
    ``EngineProfile`` when ``profile=True`` (per-segment profiles folded
    into one via ``EngineProfile.absorb`` and left on
    ``sim.last_profile``).  With ``checkpoint_events`` every event
    boundary is also snapshotted (``resilience.checkpoint``) and the
    call returns ``(result, [Snapshot, ...])``.
    """
    if timeline is None or timeline.empty:
        out = sim.run(max_cycles=max_cycles, engine=engine, profile=profile)
        return (out, []) if checkpoint_events else out
    from repro.core.noc.resilience.checkpoint import checkpoint

    total = None
    snaps = []
    t = 0
    r = 0

    def _segment(**kw):
        nonlocal total, r
        out = sim.run(max_cycles=max_cycles, engine=engine,
                      profile=profile, **kw)
        if profile:
            total = out if total is None else (total.absorb(out) or total)
            r = out.makespan
        else:
            r = out
        return r

    for ev in timeline:
        if all(st.done_cycle is not None for st in sim.streams):
            break
        _segment(stop_at=ev.cycle, start_cycle=t)
        t = ev.cycle
        if r == ev.cycle and any(st.done_cycle is None
                                 for st in sim.streams):
            if checkpoint_events:
                snaps.append(checkpoint(sim, ev.cycle))
            apply_fault_event(sim, ev)
    if any(st.done_cycle is None for st in sim.streams):
        _segment(start_cycle=t)
    if profile:
        sim.last_profile = total
        return (total, snaps) if checkpoint_events else total
    return (r, snaps) if checkpoint_events else r
