"""Telemetry subsystem benchmarks: overhead, parity, hot-link tables.

The observability layer (``repro.core.noc.telemetry``) promises two
things this module measures and gates:

* **Zero overhead when off**: ``run(telemetry=None)`` is the exact code
  path every committed baseline was produced with — the smoke gate
  replays the 16x16 storm with telemetry off and requires the makespan
  to match the committed ``BENCH_engine.json`` fingerprint bit-exactly.
* **Cheap when on**: counters accumulate at beat-advance granularity
  (per-unit fire arrays in the heap hot loop, folded once at run exit),
  so the counters-on heap wall on the storm16 must stay within 1.15x of
  the telemetry-off wall.

Rows in ``BENCH_telemetry.json``:

* ``overhead`` — storm16 heap engine-only wall, telemetry off vs
  counters on (best of reps), plus the busy-beat totals collected.
* ``engine_parity`` — per-(link, VC) busy totals on the same workload
  across cycle/event/heap/shard (must agree exactly).
* ``hot_links_routing`` / ``hot_links_faulted`` — top-k hot-link tables
  for a routed transpose scenario and the 2-dead-link storm (the same
  tables ``bench_routing`` / ``bench_faults`` embed, summarized here).

Run standalone as a CI gate::

    PYTHONPATH=src python -m benchmarks.bench_telemetry --smoke
"""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

from repro.core.noc.faults import FaultSet
from repro.core.noc.netsim import NoCSim
from repro.core.noc.params import PAPER_MICRO
from repro.core.noc.program import from_trace
from repro.core.noc.program.lower import add_op
from repro.core.noc.program.ops import BarrierOp
from repro.core.noc.telemetry import Collector, perfetto_json
from repro.core.noc.traffic import (
    SyntheticConfig,
    collective_storm,
    replay,
    synthetic_trace,
)
from repro.core.topology import Mesh2D

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_telemetry.json"
ENGINE_JSON = Path(__file__).resolve().parent.parent / "BENCH_engine.json"

OVERHEAD_BUDGET = 1.15  # counters-on heap wall budget vs telemetry-off

PARITY_ENGINES = ("cycle", "event", "heap", "shard:2x2:1")


def _storm_engine_wall(mesh_side: int, engine: str, phases: int = 2,
                       with_telemetry: bool = False, reps: int = 3):
    """Engine-only storm wall (lowering excluded, best of ``reps`` — the
    ``bench_engine`` timing idiom), optionally with a collector attached.
    Returns (best wall, makespan, collector of the best rep)."""
    mesh = Mesh2D(mesh_side, mesh_side)
    prog = from_trace(collective_storm(mesh, tile_bytes=2048, phases=phases))
    p = PAPER_MICRO
    by_phase: dict[int, list] = {}
    for op in prog.ops:
        by_phase.setdefault(op.phase, []).append(op)
    best = float("inf")
    best_col = None
    makespan = 0
    for _ in range(reps):
        sim = NoCSim(mesh, p)
        col = Collector() if with_telemetry else None
        offset = 0.0
        wall = 0.0
        for phase in range(prog.num_phases):
            barrier_cost = 0.0
            for op in by_phase.get(phase, ()):
                if isinstance(op, BarrierOp):
                    barrier_cost = max(barrier_cost, op.cost(p))
                    continue
                add_op(sim, op, offset + op.start, p)
            t0 = time.perf_counter()
            done = sim.run(engine="heap" if engine == "heap" else engine,
                           telemetry=col)
            wall += time.perf_counter() - t0
            makespan = done
            offset = max(offset, done) + barrier_cost
        if wall < best:
            best = wall
            best_col = col
    return best, makespan, best_col


def _overhead_record(mesh_side: int = 16) -> dict:
    off_wall, off_mk, _ = _storm_engine_wall(mesh_side, "heap")
    on_wall, on_mk, col = _storm_engine_wall(mesh_side, "heap",
                                            with_telemetry=True)
    if off_mk != on_mk:
        raise AssertionError(
            f"telemetry changed the storm{mesh_side} makespan: "
            f"{off_mk} -> {on_mk}")
    stats = col.stats()
    return {
        "mesh": mesh_side,
        "engine": "heap",
        "makespan": off_mk,
        "wall_off_s": round(off_wall, 4),
        "wall_on_s": round(on_wall, 4),
        "overhead_x": round(on_wall / max(off_wall, 1e-9), 3),
        "budget_x": OVERHEAD_BUDGET,
        "busy_beats": stats.total_busy_beats(),
        "links_touched": len(stats.link_busy),
    }


def _parity_workload(side: int = 8):
    trace = synthetic_trace(Mesh2D(side, side), SyntheticConfig(
        pattern="transpose", rate=0.1, nbytes=256, packets_per_node=4,
        seed=0,
    ))
    return trace


def _parity_record(side: int = 8) -> dict:
    """Busy-beat totals per engine on the same workload — the tentpole's
    cross-engine equality claim, reported (the test suite asserts it on a
    richer mixed workload)."""
    trace = _parity_workload(side)
    totals = {}
    stats_by_engine = {}
    for engine in PARITY_ENGINES:
        col = Collector()
        replay(trace, params=PAPER_MICRO, engine=engine, telemetry=col)
        st = col.stats()
        stats_by_engine[engine] = st
        totals[engine] = {
            "busy_beats": st.total_busy_beats(),
            "inject_beats": sum(st.tile_inject.values()),
            "eject_beats": sum(st.tile_eject.values()),
        }
    base = stats_by_engine[PARITY_ENGINES[0]]
    agree = all(stats_by_engine[e] == base for e in PARITY_ENGINES[1:])
    return {"mesh": side, "pattern": "transpose", "engines": totals,
            "identical": agree}


def _hot_links_routing(side: int = 16, k: int = 5) -> dict:
    trace = synthetic_trace(Mesh2D(side, side), SyntheticConfig(
        pattern="transpose", rate=0.18, nbytes=256, packets_per_node=8,
        seed=0,
    ))
    out = {}
    for policy in ("xy", "o1turn"):
        col = Collector()
        replay(trace, params=PAPER_MICRO, routing=policy, num_vcs=2,
               telemetry=col)
        st = col.stats()
        table = st.link_table(k)
        out[policy] = {
            "peak_link_utilization": table[0]["utilization"] if table else 0.0,
            "hot_links": table,
        }
    return {"mesh": side, "pattern": "transpose", "policies": out}


def _hot_links_faulted(side: int = 16, k: int = 5) -> dict:
    fs = FaultSet.sample(Mesh2D(side, side), dead_links=1, flaky_links=2,
                         seed=1)
    mesh = Mesh2D(side, side)
    prog = from_trace(collective_storm(mesh, tile_bytes=2048, phases=1))
    p = dataclasses.replace(PAPER_MICRO, faults=fs)
    sim = NoCSim(mesh, p)
    col = Collector()
    for op in prog.ops:
        if not isinstance(op, BarrierOp):
            add_op(sim, op, op.start, p)
    sim.run(engine="heap", telemetry=col)
    st = col.stats()
    table = st.link_table(k)
    return {
        "mesh": side,
        "dead_links": 1,
        "flaky_links": 2,
        "seed": 1,
        "makespan": st.makespan,
        "total_retries": st.total_retries(),
        "peak_link_utilization": table[0]["utilization"] if table else 0.0,
        "hot_links": table,
    }


def rows():
    results = {
        "overhead": _overhead_record(),
        "engine_parity": _parity_record(),
        "hot_links_routing": _hot_links_routing(),
        "hot_links_faulted": _hot_links_faulted(),
    }
    from benchmarks.run import provenance

    results["provenance"] = provenance()
    JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")
    ov = results["overhead"]
    par = results["engine_parity"]
    hr = results["hot_links_routing"]["policies"]
    hf = results["hot_links_faulted"]
    return [
        ("overhead", ov["wall_on_s"] * 1e6,
         f"off={ov['wall_off_s']}s;x{ov['overhead_x']};"
         f"budget=x{ov['budget_x']};busy={ov['busy_beats']}"),
        ("engine_parity", 0.0,
         f"identical={par['identical']};"
         f"busy={par['engines']['heap']['busy_beats']}"),
        ("hot_links_routing", 0.0,
         f"xy_peak={hr['xy']['peak_link_utilization']};"
         f"o1turn_peak={hr['o1turn']['peak_link_utilization']}"),
        ("hot_links_faulted", 0.0,
         f"peak={hf['peak_link_utilization']};"
         f"retries={hf['total_retries']}"),
    ]


def smoke() -> int:
    """CI gate for the telemetry subsystem.

    * Telemetry-off storm16 must reproduce the committed
      ``BENCH_engine.json`` makespan fingerprint bit-exactly.
    * Counters-on heap wall within ``OVERHEAD_BUDGET`` of off.
    * All four engines produce identical FabricStats on one workload.
    * The Perfetto export round-trips ``json.loads`` with monotonic
      span timestamps.
    """
    ov = _overhead_record()
    print(json.dumps(ov, indent=2))
    expected = None
    if ENGINE_JSON.exists():
        expected = json.loads(ENGINE_JSON.read_text()).get(
            "storm16", {}).get("makespan")
    if expected is not None and ov["makespan"] != expected:
        print(f"FAIL: telemetry-off storm16 makespan {ov['makespan']} != "
              f"committed fingerprint {expected} (BENCH_engine.json)")
        return 1
    if ov["overhead_x"] > OVERHEAD_BUDGET:
        print(f"FAIL: counters-on overhead x{ov['overhead_x']} exceeds "
              f"budget x{OVERHEAD_BUDGET}")
        return 1
    par = _parity_record()
    if not par["identical"]:
        print(f"FAIL: engines disagree on FabricStats: {par['engines']}")
        return 1
    # Perfetto round trip on a spanned run.
    col = Collector()
    replay(_parity_workload(8), params=PAPER_MICRO, telemetry=col)
    data = json.loads(perfetto_json(col))
    events = data["traceEvents"]
    ts = [e["ts"] for e in events if e["ph"] != "M"]
    if not events or ts != sorted(ts):
        print("FAIL: Perfetto export is empty or has non-monotonic "
              "span timestamps")
        return 1
    print(f"OK: off bit-identical at {ov['makespan']}; overhead "
          f"x{ov['overhead_x']} <= x{OVERHEAD_BUDGET}; engines agree; "
          f"Perfetto round-trips with {len(events)} events")
    return 0


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        sys.exit(smoke())
    for name, us, derived in rows():
        print(f"{name},{us},{derived}")
