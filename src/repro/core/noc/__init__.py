"""Cycle-level substrate reproducing the paper's own evaluation.

``params``    — hardware/runtime parameter sets (+ TPU-pod mapping)
``model``     — the paper's analytical runtime models, Eqs (1)-(6), (10)-(15)
``netsim``    — flit-level 2-D-mesh simulator (multicast fork / reduction
                join); streams keep exact Fraction beat arithmetic and
                expose both per-call (``requests``) and incremental
                (``ready_units``/``advance_unit``) readiness; routes and
                collective trees come from the configured routing policy,
                and every stream carries the virtual channel of its
                traffic class
``routing``   — router microarchitecture subsystem:
                ``routing.policies``  pluggable deterministic minimal
                                      routing — ``xy`` (reference),
                                      ``yx``, ``o1turn`` (cycle-balanced
                                      XY/YX split), ``oddeven`` (Chiu's
                                      turn model, deterministic
                                      load-spreading selection);
                                      ``NoCParams.routing`` selects
                ``routing.turns``     exact channel-dependency-graph
                                      deadlock-freedom checks per route
                                      class (O1TURN needs a VC per class)
                ``routing.trees``     policy-generic multicast fork /
                                      reduction join tree builders,
                                      bit-identical to the legacy XY
                                      builders for ``xy``, memoized on
                                      (policy, mesh, addresses)
``engine``    — bit-identical run loops: ``heap`` (default; global
                min-heap keyed on exact next-ready cycle, lazy
                invalidation, Fenwick-tracked round-robin positions,
                incremental per-unit readiness), ``event`` (idle-gap
                fast-forward, O(streams) per active cycle) and ``cycle``
                (the per-cycle reference loop).  Identical per-stream
                arrivals, completion cycles and arbitration counter
                across all engines; all arbitrate one beat per
                (link, VC) per cycle (``NoCParams.num_vcs``, ``vc_map``
                / ``vc_select``), which degenerates to the historical
                whole-link arbitration at ``num_vcs=1``.
                ``NoCSim.run(profile=True)`` returns an
                :class:`~repro.core.noc.engine.EngineProfile` of
                scheduler counters (heap pushes/pops, lazy
                invalidations, shard epochs/boundary reconciliations).
``shard``     — ``engine='shard'`` (or ``'shard:GXxGY:W'``): the
                region-sharded replay engine for 128x128-class meshes.
                Invariants that make it exact: every unit's links share
                a source tile, so links partition by rectangular region
                (no cross-region arbitration); the round-robin order
                restricted to a region is the global order (same
                rotated live-position key); and conservatively bounded
                epochs (T = 1 + min over permanently valid lower bounds
                on boundary-unit fires and stream completions, lazily
                refreshed) freeze the live set and all cross-region
                arrivals, so per-(link, VC) arbitration runs
                independently per region — serially or on fork-worker
                processes — and reconciles boundary links at epoch
                edges.  Bit-identical to ``heap`` (arrivals, done
                cycles, ``_rr``) for every grid and worker count;
                falls back to in-process execution (with a warning)
                when workers cannot spawn.
``program``   — collective program IR, the single workload API from
                emitters to engines:
                ``program.ops``      typed op nodes (unicast / multicast /
                                     reduction / barrier / compute) with
                                     explicit dependency edges; ``Program``
                                     (trace schema v3 serialization, v1/v2
                                     loading via phase→barrier-dep
                                     conversion, lossless Trace round trip,
                                     comm/compute filters)
                ``program.builder``  fluent ``ProgramBuilder`` — the target
                                     of every emitter (``schedules``,
                                     ``summa``, ``overlap``, storms)
                ``program.lower``    one lowering pass to engine streams;
                                     ``run_program`` executes per-op
                                     dependency gating (``mode='op'``,
                                     comm/compute overlap via ComputeOp
                                     timed streams), the legacy
                                     phase-serialized semantics
                                     (``mode='barrier'``) or sliding-window
                                     overlap (``mode='window'``, endpoint
                                     tiles or policy-aware link footprints);
                                     per-op completion/latency results with
                                     percentile stats.  ``CompiledWorkload``
                                     / ``compile_workload``: lower a
                                     (mesh, params, program) once — routes,
                                     fork/join trees, stream specs, unit
                                     topologies, packet ids — and re-run it
                                     with only injection starts swapped
                                     (cache key: one spec per op of the
                                     compiled program instance)
``traffic``   — traffic engine subsystem:
                ``traffic.patterns``  seedable synthetic workloads (uniform,
                                      transpose, bit-complement, bit-reversal,
                                      hotspot, neighbor, all-to-all) and
                                      SUMMA/FCL collective storms; the
                                      rate-independent draws live in a
                                      ``SyntheticPopulation`` so sweeps
                                      re-time one population per rate
                ``traffic.trace``     TrafficEvent/Trace serialization, live
                                      TraceRecorder capture, and contended
                                      replay — a thin shim over the program
                                      IR (phase→barrier-dep conversion +
                                      ``run_program``), bit-identical to the
                                      historical phase-barrier and
                                      sliding-window modes; loads schema
                                      v1/v2/v3 files
                ``traffic.sweep``     injection-rate vs. latency/throughput
                                      saturation curves with p50/p95/p99
                                      latency tails; ``workers=N`` fans
                                      point chunks over a process pool
                                      (warning on fallback) and
                                      ``compile_once`` lowers each
                                      population one time per worker via
                                      ``CompiledWorkload``;
                                      ``compare_policies`` reports the
                                      saturation-point shift per
                                      (routing policy, VC count)
``faults``    — fault-injection subsystem (degraded-mesh execution):
                ``faults.model``    seedable ``FaultSet`` (dead links,
                                    dead routers, flaky links with
                                    duty-cycle retry cost as exact
                                    per-edge Fraction rates, CRC-32
                                    jitter); serializes into the
                                    trace/program stamp for
                                    bit-identical replay;
                                    ``NoCParams.faults`` hooks it into
                                    every engine at stream-construction
                                    time (the zero-fault path is
                                    untouched); ``surviving_submesh`` /
                                    ``degrade_program`` are the fabric
                                    mirror of ``runtime/elastic.py``
                ``faults.repair``   detour routing around dead elements
                                    on the odd-even turn model with a
                                    dedicated escape VC when
                                    ``num_vcs`` affords one, structural
                                    O(nodes) min-VC bounds
                                    (``fast_min_vcs``) agreeing with
                                    the exact enumeration, and the
                                    exact per-VC channel-dependency
                                    gate (``verify_route_deps``) every
                                    degraded run passes before
                                    executing
                ``faults.regraft``  multicast fork / reduction join
                                    trees rebuilt around faulted nodes
                                    (deepest / first-intersection
                                    grafting) with out-tree/in-tree
                                    validity checkers
``resilience`` — resilient execution layer (failures during a run, where
                ``faults`` models failures known before it):
                ``resilience.checkpoint`` deterministic snapshot/restore
                                    of a paused run at an exact cycle
                                    boundary — versioned, sha256-
                                    fingerprinted JSON; ``restore()`` +
                                    ``run(start_cycle=C)`` is
                                    bit-identical to the uninterrupted
                                    run on every engine
                ``resilience.supervise`` process-supervision primitives
                                    for the shard fork backend:
                                    poll-with-deadline receives,
                                    heartbeats, dead/wedged detection,
                                    respawn budgets and terminate→kill
                                    teardown escalation; the shard
                                    engine respawns-and-replays a lost
                                    worker from its epoch op log, or
                                    degrades to in-process execution,
                                    without changing results
                ``resilience.timeline`` seedable ``FaultTimeline`` of
                                    mid-run ``(cycle, FaultSet)``
                                    events: run to the event cycle,
                                    compose fault sets, re-lower the
                                    affected survivors through the
                                    ``faults`` detour/re-graft/escape-VC
                                    machinery (CDG gate re-verified),
                                    resume; an empty timeline is
                                    bit-identical to a plain run
``telemetry`` — opt-in fabric observability (zero overhead when off —
                ``run(telemetry=None)`` is the exact committed-baseline
                code path):
                ``telemetry.collector`` ``Collector`` attaches via
                                    ``NoCSim.run(telemetry=...)`` and
                                    accumulates per-(link, VC) busy-beat
                                    and retry counters plus per-tile
                                    inject/eject totals at beat-advance
                                    granularity — identical totals on
                                    every engine by construction (the
                                    heap/shard engines batch per-unit
                                    fire counts and fold at run exit /
                                    epoch reply); fault events annotate,
                                    program runs record per-op spans;
                                    windowed timeseries (live streams,
                                    offered vs delivered bandwidth,
                                    per-region occupancy) and stream
                                    lifecycle spans derive lazily from
                                    the attached sim; checkpoints carry
                                    collector state bit-exactly
                ``telemetry.stats`` ``FabricStats`` read-out: heatmap
                                    grids, top-k hot-link tables, ASCII
                                    rendering
                ``telemetry.perfetto`` Chrome/Perfetto ``trace_event``
                                    JSON export (comm/compute/stream/
                                    fault lanes + counter tracks) for
                                    ``ui.perfetto.dev``
``service``   — simulation-as-a-service: a persistent local evaluation
                server over the direct APIs:
                ``service.jobs``    declarative job documents (sweep /
                                    policy-compare / run-program) with
                                    canonical fingerprints and the
                                    single ``execute_workload`` path
                                    every result goes through
                ``service.cache``   compiled-workload LRU + completed-
                                    point result memo keyed on the
                                    shared ``noc.fingerprint`` keys,
                                    with exact hit/miss/eviction
                                    accounting
                ``service.scheduler`` slot-based dispatch over
                                    persistent supervised fork workers
                                    (per-client fairness, in-flight
                                    point coalescing, kill/wedge
                                    recovery with chunk retry,
                                    degradation to in-process, bounded
                                    admission with retry-after,
                                    graceful drain)
                ``service.store``   crash-safe on-disk result store:
                                    append-only torn-write-tolerant
                                    JSONL memo, hydrated at server
                                    start — restart (even ``kill -9``)
                                    survival with zero recompute
                ``service.server`` / ``service.client``  JSONL protocol
                                    over AF_UNIX and token-
                                    authenticated TCP: concurrent
                                    clients, streamed result rows,
                                    cancellation, SIGTERM drain, client
                                    reconnect/backoff with idempotent
                                    resubmission; rows are
                                    bit-identical to calling
                                    ``saturation_sweep`` /
                                    ``run_program`` directly, across
                                    server restarts
``fingerprint`` — the one canonical sha256 module behind every
                content-addressed key (sweep-journal keys, checkpoint
                fingerprints, service workload/point identities), with
                the historical byte forms preserved exactly
``energy``    — Table-1 energy model and Fig-10 scaling
``calibrate`` — validation of every numeric claim in the paper, plus
                ``load_claims``: saturation-aware checks of a sweep
                curve at a chosen offered load (not just idle-network),
                and ``fit_claims``: least-squares *recovery* of
                alpha0/beta from the linear region of measured sweep
                curves across payload sizes (round-trip tested against
                synthetic curves)
"""

from repro.core.noc.params import NoCParams, PAPER_MICRO, PAPER_GEMM  # noqa: F401
