"""The paper's analytical runtime models.

Implements, verbatim, Equations (1)-(9) and the 2-D generalizations
(10)-(15), together with the optimal-batch-count search the paper assumes
("the optimal batch size is assumed for the seq implementation") and the
``best software implementation on a case-by-case basis'' selection used in
Section 4.3.

All times are in cycles; ``n`` is a transfer size in *beats* (64 B each).

Multicast (one row, ``c`` clusters; Section 4.2.2):
  T_naive = sum_{i=1..c}     (alpha_i + n*beta + delta)      - delta     (1)
  T_seq   = sum_{i=1..k+c-1} (alpha_i + (n/k)*beta + delta)  - delta     (2)
  T_tree  = sum_{i=0..log2 c}(alpha_i + n*beta + delta)      - 2*delta   (3)
  T_hw    = alpha + (n + c - 1)*beta                                     (4)

Reduction (one row, ``c`` clusters; Section 4.2.3), with
``t_m = alpha_m + (n/k) beta_m`` and ``t_c = alpha_c + (n/k) beta_c``:
  T_seq   = t_m + 2(c-2) max(t_m,t_c) + k t_c + (2(c-2)+k) delta         (5)
  T_tree  = {t_m + delta + (k-1)[max(t_m,t_c)+delta] + t_c} log2 c       (6)

2-D forms: Eqs (10)-(15) in Appendix B.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.noc.params import NoCParams


def _log2i(v: int) -> int:
    if v < 1 or (v & (v - 1)) != 0:
        raise ValueError(f"expected a power of two, got {v}")
    return v.bit_length() - 1


# ---------------------------------------------------------------------------
# Stage-distance helpers.  alpha_i depends on the hop distance of the DMA
# transfer performed at stage i (round trip, Section 2.2).
# ---------------------------------------------------------------------------


def _naive_stage_hops(c: int, fetch_hops: int = 1) -> list[int]:
    """Naive-sequential 1-D multicast: initial fetch + c-1 neighbour copies."""
    return [fetch_hops] + [1] * (c - 1)


def _tree_stage_hops(c: int, fetch_hops: int = 1) -> list[int]:
    """Binary-tree 1-D multicast: fetch, then halving distances c/2, ..., 1."""
    return [fetch_hops] + [c >> (i + 1) for i in range(_log2i(c))]


# ---------------------------------------------------------------------------
# Multicast models (Eqs 1-4 and 10-13).
# ---------------------------------------------------------------------------


def multicast_naive(p: NoCParams, n: int, c: int, r: int = 1) -> float:
    """Eq (1) / Eq (10): naive sequential multicast to a c x r sub-grid."""
    hops = _naive_stage_hops(c)
    if r > 1:
        hops += [1] * (r - 1)  # column copies, pipelined per Fig. 11
    return sum(p.alpha(h) + n * p.beta + p.delta for h in hops) - p.delta


def multicast_seq(p: NoCParams, n: int, c: int, r: int = 1, k: int | None = None) -> float:
    """Eq (2) / Eq (11): pipelined sequential multicast with k batches."""

    def at_k(k: int) -> float:
        stages = k + c - 1 + (r - 1 if r > 1 else 0)
        # All stage transfers are neighbour copies except the initial fetch.
        total = 0.0
        for i in range(stages):
            h = 1
            total += p.alpha(h) + (n / k) * p.beta + p.delta
        return total - p.delta

    if k is not None:
        return at_k(k)
    return min(at_k(k) for k in _k_candidates(n))


def multicast_tree(p: NoCParams, n: int, c: int, r: int = 1) -> float:
    """Eq (3) / Eq (12): binary-tree multicast."""
    hops = _tree_stage_hops(c)
    if r > 1:
        hops += [r >> (i + 1) for i in range(_log2i(r))]
    return sum(p.alpha(h) + n * p.beta + p.delta for h in hops) - 2 * p.delta


def multicast_hw(p: NoCParams, n: int, c: int, r: int = 1) -> float:
    """Eq (4) / Eq (13): in-network multicast (single pipelined stream)."""
    drain = (c - 1) + (r - 1)
    return p.alpha(1) + (n + drain) * p.beta


def multicast_sw_best(p: NoCParams, n: int, c: int, r: int = 1) -> float:
    """min(T_seq, T_tree) as used throughout Section 4."""
    return min(multicast_seq(p, n, c, r), multicast_tree(p, n, c, r))


# ---------------------------------------------------------------------------
# Reduction models (Eqs 5-6 and 14-15).
# ---------------------------------------------------------------------------


def _tm_tc(p: NoCParams, n: int, k: int) -> tuple[float, float]:
    t_m = p.alpha(1) + (n / k) * p.beta
    t_c = p.alpha_c + (n / k) * p.beta_c
    return t_m, t_c


def reduction_seq(p: NoCParams, n: int, c: int, r: int = 1, k: int | None = None) -> float:
    """Eq (5) / Eq (15): pipelined sequential reduction."""

    def at_k(k: int) -> float:
        t_m, t_c = _tm_tc(p, n, k)
        mx = max(t_m, t_c)
        if r <= 1:
            return t_m + 2 * (c - 2) * mx + k * t_c + (2 * (c - 2) + k) * p.delta
        return (
            t_m
            + 2 * (c - 2) * mx
            + (k - 1) * t_c
            + mx
            + 2 * (r - 2) * mx
            + k * t_c
            + (2 * (c - 2) + 2 * (r - 2) + 2 * k) * p.delta
        )

    if k is not None:
        return at_k(k)
    return min(at_k(k) for k in _k_candidates(n))


def reduction_tree(p: NoCParams, n: int, c: int, r: int = 1, k: int | None = None) -> float:
    """Eq (6) / Eq (14): double-buffered tree reduction."""

    def at_k(k: int) -> float:
        t_m, t_c = _tm_tc(p, n, k)
        mx = max(t_m, t_c)
        stages = _log2i(c) + (_log2i(r) if r > 1 else 0)
        return (t_m + p.delta + (k - 1) * (mx + p.delta) + t_c) * stages

    if k is not None:
        return at_k(k)
    return min(at_k(k) for k in _k_candidates(n))


def reduction_hw(p: NoCParams, n: int, c: int, r: int = 1) -> float:
    """In-network reduction.

    1-D: a single pipelined stream joined along the row,
    ``alpha + (n + c - 1) beta``.  2-D: the routers in the collecting column
    see three-input joins; with the single 2-input wide-reduction unit per
    router (Section 3.1.4) the fully-reduced throughput halves — the paper
    measures a 1.9x slowdown on 32 KiB going 1-D -> 2-D (Section 4.2.3).
    """
    if r <= 1:
        return p.alpha(1) + (n + c - 1) * p.beta
    eff_beta = 2.0 * p.beta  # 3-input joins -> 2 two-input ops per beat
    return p.alpha(1) + (n * eff_beta) + (c - 1 + r - 1) * p.beta


def reduction_sw_best(p: NoCParams, n: int, c: int, r: int = 1) -> float:
    return min(reduction_seq(p, n, c, r), reduction_tree(p, n, c, r))


def _k_candidates(n: int) -> list[int]:
    """Batch counts searched for the optimal-k schedules.

    Dense up to 64 (where the optimum of Eq. 2/5 lives for realistic
    alpha/delta), coarse beyond, always including k = n (the Fig. 5b
    beat-granularity limit)."""
    ks = set(range(1, min(64, max(1, n)) + 1))
    ks.update({80, 96, 128, 192, 256, 384, 512, 768, 1024, max(1, n)})
    return sorted(k for k in ks if k <= max(1, n))


# ---------------------------------------------------------------------------
# GEMM-level models (Section 4.3).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GemmPoint:
    """One steady-state iteration of a distributed GEMM on an s x s mesh."""

    mesh: int
    t_comp: float
    t_comm_sw: float
    t_comm_hw: float

    @property
    def t_sw(self) -> float:
        return max(self.t_comp, self.t_comm_sw)

    @property
    def t_hw(self) -> float:
        return max(self.t_comp, self.t_comm_hw)

    @property
    def speedup(self) -> float:
        return self.t_sw / self.t_hw

    @property
    def sw_bound(self) -> str:
        return "comm" if self.t_comm_sw > self.t_comp else "comp"

    @property
    def hw_bound(self) -> str:
        return "comm" if self.t_comm_hw > self.t_comp else "comp"


def summa_point(p: NoCParams, mesh: int, tile: int = 16, dtype_bytes: int = 8) -> GemmPoint:
    """SUMMA steady-state iteration (Section 4.3.1, Fig. 9a).

    Each cluster computes a ``tile^3`` sub-problem; A_{i,k} is multicast
    along row i and B_{k,j} along column j.  The software path serializes
    the two collectives on the cluster DMA engine; the hardware path streams
    them from independent memory tiles concurrently (see NoCParams).
    """
    n = p.beats(tile * tile * dtype_bytes)
    t_comp = (tile**3) / (p.gemm_utilization * p.macs_per_cycle)
    one_sw = multicast_sw_best(p, n, mesh)
    one_hw = multicast_hw(p, n, mesh)
    t_comm_sw = 2 * one_sw if p.sw_gemm_serializes_ab else one_sw
    t_comm_hw = max(one_hw, one_hw)  # A and B streams overlap
    return GemmPoint(mesh, t_comp, t_comm_sw, t_comm_hw)


def fcl_point(p: NoCParams, mesh: int, tile: int = 16, dtype_bytes: int = 8) -> GemmPoint:
    """FusedConcatLinear GEMM (Section 4.3.2, Fig. 9b).

    A GEMM distributed along K (one attention head per cluster); the
    partial C tiles are reduced across the full mesh.  The reduction phase
    strictly follows compute (footnote 8), so runtime is additive:
    ``T = T_comp + T_red``.
    """
    n = p.beats(tile * tile * dtype_bytes)
    t_comp = (tile**3) / (p.gemm_utilization * p.macs_per_cycle)
    red_sw = reduction_sw_best(p, n, mesh, r=mesh if mesh > 1 else 1)
    red_hw = reduction_hw(p, n, mesh, r=mesh if mesh > 1 else 1)
    # Additive composition (communication always on the critical path here):
    return GemmPoint(
        mesh,
        t_comp=0.0,  # unused for additive composition; keep totals below
        t_comm_sw=t_comp + red_sw,
        t_comm_hw=t_comp + red_hw,
    )


def fcl_speedup(p: NoCParams, mesh: int, tile: int = 16) -> float:
    pt = fcl_point(p, mesh, tile)
    return pt.t_comm_sw / pt.t_comm_hw


def summa_sweep(p: NoCParams, meshes=(4, 8, 16, 32, 64, 128, 256), tile: int = 16):
    return [summa_point(p, m, tile) for m in meshes]


def fcl_sweep(p: NoCParams, meshes=(2, 4, 8, 16, 32, 64, 128, 256), tile: int = 16):
    return [(m, fcl_speedup(p, m, tile)) for m in meshes]


# ---------------------------------------------------------------------------
# Barrier model (Section 4.2.1, Fig. 2b).
# ---------------------------------------------------------------------------


def barrier_sw(p: NoCParams, clusters: int) -> float:
    return p.barrier_sw(clusters)


def barrier_hw(p: NoCParams, clusters: int) -> float:
    return p.barrier_hw(clusters)


def geomean(vals) -> float:
    vals = [v for v in vals if v > 0]
    return math.exp(sum(math.log(v) for v in vals) / len(vals)) if vals else 0.0
