"""Collective-program benchmark: comm/compute overlap + shim fidelity.

The trajectory guard for the program IR (the single workload path from
emitters to engines).  Two properties are measured and gated:

* **Overlap** — a 16x16 SUMMA program with per-tile ``ComputeOp`` nodes
  (double-buffered deps, see ``summa.summa_program``) must finish
  strictly earlier under per-op gating (``run_program(mode='op')``) than
  under the phase-serialized barrier baseline, and no earlier than the
  ``max(comm-only, compute-only)`` lower bound — the paper's
  communication-off-the-critical-path claim, reproduced in the contended
  simulator rather than the analytical models.
* **Shim fidelity** — the deprecated ``*_noc_events`` / ``*_noc_trace``
  emitters are thin shims over the program builder; their serialized
  output must stay bit-identical to the pre-IR generators (sha256
  fingerprints pinned when the shims were introduced).

Emits ``BENCH_program.json`` at the repo root with the measured
makespans, overlap ratios, per-op latency percentiles, and the
fingerprint checks.

Run standalone as a CI gate::

    PYTHONPATH=src python -m benchmarks.bench_program --smoke

exits non-zero if per-op gating fails to beat the barrier baseline (or
violates the lower bound) on the 8x8 program, or any shim fingerprint
drifts.
"""

from __future__ import annotations

import hashlib
import json
import time
import warnings
from pathlib import Path

from repro.core.noc.params import PAPER_MICRO
from repro.core.noc.program import run_program
from repro.core.summa import summa_program
from repro.core.topology import Coord, Mesh2D

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_program.json"

# sha256[:16] of the legacy emitters' serialized output, captured from the
# pre-IR generators at the commit that introduced the shims.  A drift here
# means the builder path silently changed workload content.
GOLDEN_SHIMS = {
    "broadcast_tree_8": "30f0300af8005a90",
    "all_reduce_native_8": "ca4737a2f9acc989",
    "summa4_native": "6fe2d4a63785b259",
    "summa16_native": "268e6dc06073c22a",
    "ag_ring_4": "12f987c989d01c17",
    "rs_ring_4": "a9d580d7236c89be",
}


def _h(s: str) -> str:
    return hashlib.sha256(s.encode()).hexdigest()[:16]


def shim_fingerprints() -> dict[str, str]:
    """Serialize every deprecated shim's output (warnings suppressed —
    exercising the shims is this benchmark's job)."""
    from repro.core import schedules as sched
    from repro.core.overlap import ag_matmul_noc_trace, matmul_rs_noc_trace
    from repro.core.summa import summa_noc_trace

    row8 = [Coord(x, 0) for x in range(8)]
    row4 = [Coord(x, 0) for x in range(4)]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        events = lambda evs: json.dumps(  # noqa: E731
            [e.to_dict() for e in evs], sort_keys=True)
        return {
            "broadcast_tree_8": _h(events(sched.broadcast_noc_events(
                row8, 2, 8192, schedule="tree", chunks=4, params=PAPER_MICRO))),
            "all_reduce_native_8": _h(events(sched.all_reduce_noc_events(
                row8, 8192, schedule="native", params=PAPER_MICRO))),
            "summa4_native": _h(summa_noc_trace(
                Mesh2D(4, 4), 2048, schedule="native").to_json()),
            "summa16_native": _h(summa_noc_trace(
                Mesh2D(16, 16), 2048, schedule="native").to_json()),
            "ag_ring_4": _h(ag_matmul_noc_trace(
                Mesh2D(4, 4), row4, 2048).to_json()),
            "rs_ring_4": _h(matmul_rs_noc_trace(
                Mesh2D(4, 4), row4, 2048).to_json()),
        }


def overlap_record(side: int, iters: int, tile_bytes: int = 2048,
                   schedule: str = "native") -> dict:
    """Measure one SUMMA-with-compute program under all compositions."""
    mesh = Mesh2D(side, side)
    prog = summa_program(mesh, tile_bytes, schedule=schedule, iters=iters,
                         compute_cycles="model")
    t0 = time.perf_counter()
    op = run_program(prog, PAPER_MICRO, mode="op")
    barrier = run_program(prog, PAPER_MICRO, mode="barrier")
    comm = run_program(prog.comm_only(), PAPER_MICRO, mode="op")
    comp = run_program(prog.compute_only(), PAPER_MICRO, mode="op")
    wall = time.perf_counter() - t0
    stats = op.stats()
    lower = max(comm.makespan, comp.makespan)
    return {
        "mesh": f"{side}x{side}",
        "schedule": schedule,
        "iters": iters,
        "tile_bytes": tile_bytes,
        "ops": len(prog.ops),
        "makespan_op": op.makespan,
        "makespan_barrier": round(barrier.makespan, 1),
        "makespan_comm_only": comm.makespan,
        "makespan_compute_only": comp.makespan,
        "overlap_ratio": round(barrier.makespan / op.makespan, 4),
        "headroom_vs_lower_bound": round(op.makespan / lower, 4),
        "op_latency": {
            "mean": round(stats.mean, 1), "p50": stats.p50,
            "p95": stats.p95, "p99": stats.p99, "max": stats.max,
        },
        "claims": {
            "op_below_barrier": op.makespan < barrier.makespan,
            "op_at_least_lower_bound": op.makespan >= lower,
        },
        "wall_s": round(wall, 2),
    }


def rows():
    results = {
        "overlap": [
            overlap_record(16, iters=8),
            overlap_record(16, iters=4, schedule="tree"),
            overlap_record(8, iters=8),
        ],
        "shim_fingerprints": {},
    }
    got = shim_fingerprints()
    results["shim_fingerprints"] = {
        name: {"sha": sha, "matches_legacy": sha == GOLDEN_SHIMS[name]}
        for name, sha in got.items()
    }
    from benchmarks.run import provenance

    results["provenance"] = provenance()
    JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")
    out = []
    for rec in results["overlap"]:
        name = f"summa{rec['mesh']}_{rec['schedule']}_i{rec['iters']}"
        ok = all(rec["claims"].values())
        out.append((name, rec["wall_s"] * 1e6,
                    f"op={rec['makespan_op']};barrier={rec['makespan_barrier']};"
                    f"overlap_x={rec['overlap_ratio']};bounds_ok={ok}"))
    n_match = sum(1 for v in results["shim_fingerprints"].values()
                  if v["matches_legacy"])
    out.append(("shim_fingerprints", 0.0,
                f"{n_match}/{len(GOLDEN_SHIMS)}_match_legacy"))
    return out


def smoke() -> int:
    """CI gate: overlap must pay and the shims must not drift."""
    rec = overlap_record(8, iters=4)
    print(json.dumps(rec, indent=2))
    if not rec["claims"]["op_below_barrier"]:
        print("FAIL: per-op gating does not beat the barrier baseline")
        return 1
    if not rec["claims"]["op_at_least_lower_bound"]:
        print("FAIL: per-op makespan below the max(comm, compute) bound "
              "(overlap model is optimistic)")
        return 1
    got = shim_fingerprints()
    bad = [k for k, v in got.items() if v != GOLDEN_SHIMS[k]]
    if bad:
        print(f"FAIL: shim output drifted from the legacy emitters: {bad}")
        return 1
    print(f"OK: overlap {rec['overlap_ratio']}x over barrier replay, "
          f">= lower bound; {len(got)} shim fingerprints match legacy")
    return 0


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        sys.exit(smoke())
    for name, us, derived in rows():
        print(f"{name},{us},{derived}")
