"""Multi-device validation of SUMMA / FCL / overlapped collective matmuls."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fcl import fcl_sharded
from repro.core.overlap import ag_matmul_sharded, matmul_rs_sharded
from repro.core.summa import summa_sharded

mesh22 = jax.make_mesh((2, 2), ("row", "col"),
                       devices=jax.devices()[:4],
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
mesh8 = jax.make_mesh((8,), ("model",),
                      axis_types=(jax.sharding.AxisType.Auto,))


def check_summa():
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (32, 64), jnp.float32)
    B = jax.random.normal(jax.random.PRNGKey(1), (64, 48), jnp.float32)
    ref = np.asarray(A @ B)
    for schedule in ("native", "chain", "pipelined", "tree", "ring"):
        with jax.set_mesh(mesh22):
            C = summa_sharded(A, B, mesh22, row_axis="row", col_axis="col",
                              schedule=schedule, chunks=2)
        np.testing.assert_allclose(np.asarray(C), ref, rtol=2e-4, atol=2e-4,
                                   err_msg=f"summa {schedule}")
    print("summa ok")


def check_fcl():
    attn = jax.random.normal(jax.random.PRNGKey(2), (16, 64), jnp.float32)
    wo = jax.random.normal(jax.random.PRNGKey(3), (64, 24), jnp.float32)
    ref = np.asarray(attn @ wo)
    for schedule in ("native", "chain", "pipelined", "tree"):
        with jax.set_mesh(mesh8):
            y = fcl_sharded(attn, wo, mesh8, axis="model", schedule=schedule)
        np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4,
                                   err_msg=f"fcl {schedule}")
    with jax.set_mesh(mesh8):
        y = fcl_sharded(attn, wo, mesh8, axis="model", schedule="native", scatter=True)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4,
                               err_msg="fcl scatter")
    print("fcl ok")


def check_overlap():
    x = jax.random.normal(jax.random.PRNGKey(4), (32, 16), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(5), (16, 40), jnp.float32)
    ref = np.asarray(x @ w)
    with jax.set_mesh(mesh8):
        y = ag_matmul_sharded(x, w, mesh8, axis="model")
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4,
                               err_msg="ag_matmul")

    x2 = jax.random.normal(jax.random.PRNGKey(6), (32, 64), jnp.float32)
    w2 = jax.random.normal(jax.random.PRNGKey(7), (64, 24), jnp.float32)
    ref2 = np.asarray(x2 @ w2)
    with jax.set_mesh(mesh8):
        y2 = matmul_rs_sharded(x2, w2, mesh8, axis="model")
    np.testing.assert_allclose(np.asarray(y2), ref2, rtol=2e-4, atol=2e-4,
                               err_msg="matmul_rs")
    print("overlap ok")


if __name__ == "__main__":
    check_summa()
    check_fcl()
    check_overlap()
    print("ALL OK")
