"""Opt-in fabric observability: counters, spans, timelines.

- ``collector`` — :class:`Collector` / :class:`TelemetryConfig`: attach
  via ``NoCSim.run(telemetry=Collector())``; accumulates per-(link, VC)
  busy-beat and retry counters, per-tile inject/eject totals, fault
  annotations and program-op spans across all four engines (identical
  totals by construction), survives checkpoints bit-exactly.
- ``stats`` — :class:`FabricStats` read-out: heatmap grids, top-k
  hot-link tables, ASCII rendering (:func:`render_heatmap`).
- ``perfetto`` — Chrome/Perfetto ``trace_event`` export
  (:func:`trace_events`, :func:`perfetto_json`) for ``ui.perfetto.dev``.

Telemetry never feeds back into simulation: ``run(telemetry=None)``
(the default) is the exact code path every committed fingerprint and
``BENCH_*.json`` baseline was produced with.
"""

from repro.core.noc.telemetry.collector import Collector, TelemetryConfig
from repro.core.noc.telemetry.perfetto import perfetto_json, trace_events
from repro.core.noc.telemetry.stats import (
    FabricStats,
    link_label,
    render_heatmap,
)

__all__ = [
    "Collector",
    "TelemetryConfig",
    "FabricStats",
    "link_label",
    "render_heatmap",
    "trace_events",
    "perfetto_json",
]
