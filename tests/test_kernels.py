"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis.

All kernels run in interpret mode (CPU container); the same pallas_call
lowers to Mosaic on TPU.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gemm import gemm
from repro.kernels.reduce_nway import reduce_nway
from repro.kernels.rglru import rglru_scan
from repro.kernels.rwkv6 import wkv


def _rand(key, shape, dtype):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * 0.5).astype(dtype)


# -- GEMM --------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(32, 32, 32), (64, 32, 16), (16, 48, 64)])
def test_gemm_matches_ref(shape, dtype):
    M, K, N = shape
    a, b = _rand(0, (M, K), dtype), _rand(1, (K, N), dtype)
    out = gemm(a, b, bm=16, bn=16, bk=16)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref.gemm_ref(a, b), np.float32),
                               rtol=tol, atol=tol)


def test_gemm_accumulate_epilogue():
    """The DCA analogue: C_out = C_in + A @ B reduced by the consumer."""
    a, b = _rand(0, (32, 32), jnp.float32), _rand(1, (32, 32), jnp.float32)
    c = _rand(2, (32, 32), jnp.float32)
    out = gemm(a, b, c, bm=16, bn=16, bk=16, accumulate=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.gemm_ref(a, b, c, accumulate=True)),
                               rtol=1e-5, atol=1e-5)


@given(
    m=st.sampled_from([16, 32, 48]),
    k=st.sampled_from([16, 32]),
    n=st.sampled_from([16, 32]),
    bk=st.sampled_from([8, 16]),
)
@settings(max_examples=10, deadline=None)
def test_gemm_property_tilings(m, k, n, bk):
    a, b = _rand(3, (m, k), jnp.float32), _rand(4, (k, n), jnp.float32)
    out = gemm(a, b, bm=16, bn=16, bk=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.gemm_ref(a, b)),
                               rtol=1e-5, atol=1e-5)


# -- N-way reduction ----------------------------------------------------------


@pytest.mark.parametrize("op,dtype", [("add", jnp.float32), ("max", jnp.float32),
                                      ("and", jnp.int32)])
def test_reduce_nway(op, dtype):
    if dtype == jnp.int32:
        x = jax.random.randint(jax.random.PRNGKey(0), (5, 256), 0, 2).astype(dtype)
    else:
        x = _rand(0, (5, 256), dtype)
    out = reduce_nway(x, op=op, bs=128)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref.reduce_nway_ref(x, op), np.float32),
                               rtol=1e-5, atol=1e-5)


def test_reduce_nway_lsb_and_barrier_semantics():
    """LsbAnd: result is 1 iff every participant has arrived (bit set)."""
    arrived = jnp.ones((8, 128), jnp.int32)
    missing = arrived.at[3].set(0)
    assert int(reduce_nway(arrived, op="and", bs=128)[0]) == 1
    assert int(reduce_nway(missing, op="and", bs=128)[0]) == 0


# -- flash attention -----------------------------------------------------------


@pytest.mark.parametrize("window", [0, 32])
@pytest.mark.parametrize("S,d", [(128, 32), (256, 16)])
def test_flash_attention(S, d, window):
    q, k, v = (_rand(i, (4, S, d), jnp.float32) for i in range(3))
    out = flash_attention(q, k, v, window=window, bq=64, bkv=64)
    expected = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-4, atol=2e-4)


@given(bq=st.sampled_from([32, 64, 128]), bkv=st.sampled_from([32, 64]))
@settings(max_examples=6, deadline=None)
def test_flash_attention_block_shape_invariance(bq, bkv):
    q, k, v = (_rand(i + 10, (2, 128, 16), jnp.float32) for i in range(3))
    out = flash_attention(q, k, v, bq=bq, bkv=bkv)
    expected = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-4, atol=2e-4)


# -- RG-LRU scan ---------------------------------------------------------------


@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_rglru_scan(chunk):
    B, S, W = 2, 128, 16
    a = jax.nn.sigmoid(_rand(0, (B, S, W), jnp.float32))  # decay in (0,1)
    b = _rand(1, (B, S, W), jnp.float32)
    out = rglru_scan(a, b, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.rglru_scan_ref(a, b)),
                               rtol=1e-4, atol=1e-4)


def test_rglru_matches_model_associative_scan():
    from repro.models.rglru import _lru_scan

    B, S, W = 2, 64, 8
    a = jax.nn.sigmoid(_rand(2, (B, S, W), jnp.float32))
    b = _rand(3, (B, S, W), jnp.float32)
    np.testing.assert_allclose(np.asarray(rglru_scan(a, b, chunk=32)),
                               np.asarray(_lru_scan(a, b)), rtol=1e-4, atol=1e-4)


# -- RWKV-6 WKV ----------------------------------------------------------------


@pytest.mark.parametrize("chunk,S", [(16, 64), (32, 64), (64, 128)])
def test_wkv_matches_sequential_ref(chunk, S):
    BH, hd = 3, 16
    r, k, v = (_rand(i, (BH, S, hd), jnp.float32) for i in range(3))
    logw = -jnp.exp(jnp.clip(_rand(3, (BH, S, hd), jnp.float32) - 2.0, -8, 1))
    u = _rand(4, (BH, hd), jnp.float32)
    out = wkv(r, k, v, logw, u, chunk=chunk)
    expected = ref.wkv_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-3, atol=2e-3)


def test_wkv_matches_model_chunked():
    from repro.models.rwkv6 import chunked_wkv

    B, S, H, hd = 2, 64, 2, 16
    r, k, v = (_rand(i + 20, (B, S, H, hd), jnp.float32) for i in range(3))
    logw = -jnp.exp(jnp.clip(_rand(23, (B, S, H, hd), jnp.float32) - 2.0, -8, 1))
    u = _rand(24, (H, hd), jnp.float32)
    out_model, _ = chunked_wkv(r, k, v, logw, u, jnp.zeros((B, H, hd, hd)))
    rk = r.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kk = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vk = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    lw = logw.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    uu = jnp.tile(u, (B, 1))
    out_k = wkv(rk, kk, vk, lw, uu, chunk=32)
    out_k = out_k.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_model),
                               rtol=2e-3, atol=2e-3)
