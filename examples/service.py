"""Simulation-as-a-service walkthrough: one persistent server, many
cheap clients.

Core-only (no JAX needed).  Start a :class:`SimulationServer` on a
local socket, then drive it the way a design-space exploration session
actually does: two clients submit overlapping saturation grids
concurrently (the service computes each unique point once and coalesces
the overlap), a third streams rows as chunks complete instead of
waiting for the batch, a resubmission returns instantly from the result
memo, and the point-exact service counters show where every row came
from.  Every row is bit-identical to calling ``saturation_sweep``
directly — the demo asserts it.

  PYTHONPATH=src python examples/service.py
"""

import threading
import time


GRID = dict(mesh=(8, 8), pattern="transpose",
            rates=[0.02, 0.04, 0.06, 0.08, 0.1, 0.12],
            packets_per_node=4, seed=7)


def main():
    from repro.core.noc.service import ServiceClient, SimulationServer
    from repro.core.noc.traffic.sweep import saturation_sweep
    from repro.core.topology import Mesh2D

    with SimulationServer(workers=2, chunk_tokens=2) as srv:
        print(f"service listening on {srv.path}")

        # -- two clients, overlapping grids, concurrently ----------------
        results = {}

        def explore(name, extra_rates):
            kw = dict(GRID)
            kw["rates"] = GRID["rates"] + extra_rates
            with ServiceClient(srv.path) as cli:
                t0 = time.perf_counter()
                results[name] = (cli.submit_sweep(**kw).sweep_points(),
                                 time.perf_counter() - t0)

        t_a = threading.Thread(target=explore, args=("alice", [0.14]))
        t_b = threading.Thread(target=explore, args=("bob", [0.16]))
        t_a.start(); t_b.start(); t_a.join(); t_b.join()
        for name, (pts, wall) in results.items():
            print(f"  {name}: {len(pts)} points in {wall:.2f}s "
                  f"(saturation knee region: mean latency "
                  f"{pts[0].mean_latency:.1f} -> {pts[-1].mean_latency:.1f} "
                  f"cycles)")

        # -- streamed rows: act on early points before the grid finishes -
        with ServiceClient(srv.path) as cli:
            h = cli.submit_sweep(**GRID)    # fully overlaps alice's grid
            t0 = time.perf_counter()
            for idx, row in h.iter_rows():
                print(f"  streamed row {idx}: rate {row['rate']:g} -> "
                      f"mean latency {row['mean_latency']:.1f} cycles "
                      f"({(time.perf_counter() - t0) * 1e3:.0f} ms in)")

            # -- warm resubmission: served from the result memo ----------
            t0 = time.perf_counter()
            pts = cli.submit_sweep(**GRID).sweep_points()
            print(f"  warm resubmission: {len(pts)} rows in "
                  f"{(time.perf_counter() - t0) * 1e3:.1f} ms")

            # -- bit-identity with the direct API ------------------------
            direct = saturation_sweep(
                Mesh2D(*GRID["mesh"]), GRID["pattern"], GRID["rates"],
                packets_per_node=GRID["packets_per_node"],
                seed=GRID["seed"])
            assert pts == direct, "service rows must equal the direct call"
            print("  bit-identical to saturation_sweep: OK")

            # -- where did every point come from? ------------------------
            st = cli.stats()
            p = st["points"]
            print(f"  accounting: {p['total']} points requested = "
                  f"{p['computed']} computed + {p['memo_hits']} memo hits "
                  f"+ {p['inflight_joins']} in-flight joins "
                  f"(hit rate {p['hit_rate']:.2f})")
            print(f"  compile cache: {st['compile_cache']}, "
                  f"workers: {st['workers']}, degraded: {st['degraded']}")


if __name__ == "__main__":
    main()
