"""Fault-tolerance walkthrough: crash mid-run, corrupt a checkpoint, resume.

  PYTHONPATH=src python examples/fault_tolerance.py
"""

import dataclasses
import pathlib
import shutil
import tempfile

import jax

from repro.configs import get_smoke_config
from repro.data import SyntheticLMSource
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig


def main():
    workdir = pathlib.Path(tempfile.mkdtemp(prefix="repro_ft_"))
    cfg = dataclasses.replace(get_smoke_config("qwen1_5_0_5b"),
                              n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                              head_dim=16, d_ff=64, vocab=64)
    src = SyntheticLMSource(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=0)
    tcfg = TrainerConfig(adamw=AdamWConfig(lr=1e-3), ckpt_dir=str(workdir),
                         ckpt_every=5, total_steps=100)

    print("phase 1: train 12 steps, checkpointing every 5 (async, atomic)")
    t1 = Trainer(cfg, tcfg)
    t1.fit(src, steps=12, resume=False)
    print("  checkpoints on disk:", t1.ckpt.steps())

    print("phase 2: 'node failure' — new process resumes from latest")
    t2 = Trainer(cfg, tcfg)
    t2.fit(src, steps=20, resume=True)
    print(f"  resumed at step {t2.metrics_log[0]['step']}, "
          f"ran to {t2.metrics_log[-1]['step']}")

    print("phase 3: corrupt the newest checkpoint — CRC check falls back")
    newest = sorted(workdir.glob("ckpt_*"))[-1]
    (newest / "arrays.npz").write_bytes(b"bitrot")
    t3 = Trainer(cfg, tcfg)
    state = t3.init_state(jax.random.PRNGKey(0))
    _, step, _ = t3.recover(state)
    print(f"  recovered from step {step} (newest was corrupt)")

    shutil.rmtree(workdir)
    print("done")


if __name__ == "__main__":
    main()
