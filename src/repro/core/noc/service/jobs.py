"""Declarative job specs for the simulation service.

A job is a JSON document a client submits over the wire; the scheduler
decomposes it into *points* — the memoization granularity — grouped
into *workloads* (everything rate-independent, the compile-cache
granularity).  Three kinds:

``sweep``
    One :func:`~repro.core.noc.traffic.sweep.saturation_sweep`
    invocation: a seeded synthetic population swept over injection
    rates.  One workload; one point per rate.  Rows are
    ``dataclasses.asdict`` of the exact
    :class:`~repro.core.noc.traffic.sweep.SweepPoint` a direct call
    produces (bit-identical: the service executes the same
    compile-once ``measure`` path).

``policy_compare``
    One :func:`~repro.core.noc.traffic.sweep.compare_policies`
    invocation: the same population swept under every
    (routing policy, VC count) configuration.  One workload per
    (policy, VC) row; points are enumerated policy-major, then VC,
    then rate — the direct call's row order.

``run_program``
    One :func:`~repro.core.noc.program.run_program` execution of a
    schema-v3 program document.  One workload with a single point whose
    row carries the makespan, per-phase drain and per-op
    (inject, done) cycles.

Every workload carries a canonical sha256 fingerprint
(:mod:`repro.core.noc.fingerprint`) over (mesh, params, program or
population, engine); a point key appends the rate token.  Identical
submissions from different clients therefore collide in the compile
cache and result memo by construction.

:func:`execute_workload` is the *only* execution path — the worker
processes, the scheduler's in-process degradation mode and the tests
all run chunks through it, so fanned-out and serial results cannot
drift.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.core.noc.fingerprint import digest, params_doc, params_from_doc
from repro.core.noc.params import NoCParams

JOB_KINDS = ("sweep", "policy_compare", "run_program")

PROGRAM_TOKEN = "result"

# Version tag of the point-key scheme below.  The durable result store
# stamps this into its header (via ``fingerprint.store_schema_parts``):
# bump it if :func:`point_key` ever changes shape, so stores written
# under the old scheme are refused by name instead of silently missing.
POINT_KEY_SCHEME = "workload_fingerprint:json_token/v1"


# ---------------------------------------------------------------------------
# Point/workload decomposition records (scheduler-facing).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WorkloadPoints:
    """One compile-cache unit of a job: a workload document plus the
    ordered tokens (sweep rates, or :data:`PROGRAM_TOKEN`) to evaluate
    on it.  ``meta`` labels the row group (e.g. policy/VC) for clients."""

    doc: dict
    fingerprint: str
    tokens: tuple
    meta: dict

    def point_key(self, token) -> str:
        return point_key(self.fingerprint, token)


def point_key(workload_fingerprint: str, token) -> str:
    """Memo key of one (workload, token) result point."""
    return f"{workload_fingerprint}:{json.dumps(token)}"


# ---------------------------------------------------------------------------
# Job specs.
# ---------------------------------------------------------------------------


def _mesh_pair(mesh) -> tuple[int, int]:
    if hasattr(mesh, "cols"):
        return (mesh.cols, mesh.rows)
    cols, rows = mesh
    return (int(cols), int(rows))


@dataclasses.dataclass(frozen=True)
class SweepJob:
    """Declarative saturation sweep (see
    :func:`~repro.core.noc.traffic.sweep.saturation_sweep`)."""

    mesh: tuple[int, int]
    pattern: str
    rates: tuple[float, ...]
    nbytes: int = 256
    packets_per_node: int = 4
    seed: int = 0
    params: Optional[NoCParams] = None
    engine: str = "heap"
    hotspot: tuple[int, int] = (0, 0)
    hotspot_frac: float = 0.5

    kind = "sweep"

    def __post_init__(self):
        object.__setattr__(self, "mesh", _mesh_pair(self.mesh))
        object.__setattr__(self, "rates", tuple(float(r) for r in self.rates))
        object.__setattr__(self, "hotspot", tuple(self.hotspot))
        if not self.rates:
            raise ValueError("sweep job needs at least one rate")
        if any(r <= 0 for r in self.rates):
            raise ValueError(f"injection rates must be > 0, got {self.rates}")
        from repro.core.noc.traffic.patterns import PATTERNS

        if self.pattern not in PATTERNS:
            raise ValueError(
                f"unknown pattern {self.pattern!r}; one of {PATTERNS}")

    def _population_doc(self, params: Optional[NoCParams] = None,
                        engine: Optional[str] = None) -> dict:
        return {
            "kind": "sweep",
            "mesh": list(self.mesh),
            "pattern": self.pattern,
            "nbytes": self.nbytes,
            "packets_per_node": self.packets_per_node,
            "seed": self.seed,
            "hotspot": list(self.hotspot),
            "hotspot_frac": self.hotspot_frac,
            "params": params_doc(params if params is not None
                                 else self.params),
            "engine": engine or self.engine,
        }

    def to_doc(self) -> dict:
        doc = self._population_doc()
        doc["rates"] = list(self.rates)
        return doc

    @classmethod
    def from_doc(cls, doc: dict) -> "SweepJob":
        return cls(
            mesh=tuple(doc["mesh"]),
            pattern=doc["pattern"],
            rates=tuple(doc["rates"]),
            nbytes=doc.get("nbytes", 256),
            packets_per_node=doc.get("packets_per_node", 4),
            seed=doc.get("seed", 0),
            params=params_from_doc(doc["params"])
            if doc.get("params") is not None else None,
            engine=doc.get("engine", "heap"),
            hotspot=tuple(doc.get("hotspot", (0, 0))),
            hotspot_frac=doc.get("hotspot_frac", 0.5),
        )

    def fingerprint(self) -> str:
        return digest(self.to_doc())

    def workloads(self) -> list[WorkloadPoints]:
        doc = self._population_doc()
        return [WorkloadPoints(doc=doc, fingerprint=digest(doc),
                               tokens=self.rates, meta={})]


@dataclasses.dataclass(frozen=True)
class PolicyCompareJob:
    """Declarative (routing policy x VC count) sweep comparison (see
    :func:`~repro.core.noc.traffic.sweep.compare_policies`)."""

    mesh: tuple[int, int]
    pattern: str
    rates: tuple[float, ...]
    policies: tuple[str, ...] = ("xy", "yx", "o1turn", "oddeven")
    vcs: tuple[int, ...] = (1,)
    vc_select: str = "packet"
    nbytes: int = 256
    packets_per_node: int = 4
    seed: int = 0
    params: Optional[NoCParams] = None
    engine: str = "heap"
    hotspot: tuple[int, int] = (0, 0)
    hotspot_frac: float = 0.5

    kind = "policy_compare"

    def __post_init__(self):
        object.__setattr__(self, "mesh", _mesh_pair(self.mesh))
        object.__setattr__(self, "rates", tuple(float(r) for r in self.rates))
        object.__setattr__(self, "policies", tuple(self.policies))
        object.__setattr__(self, "vcs", tuple(int(v) for v in self.vcs))
        object.__setattr__(self, "hotspot", tuple(self.hotspot))
        if not (self.rates and self.policies and self.vcs):
            raise ValueError(
                "policy_compare job needs rates, policies and vcs")

    def _sweep(self) -> SweepJob:
        return SweepJob(
            mesh=self.mesh, pattern=self.pattern, rates=self.rates,
            nbytes=self.nbytes, packets_per_node=self.packets_per_node,
            seed=self.seed, params=self.params, engine=self.engine,
            hotspot=self.hotspot, hotspot_frac=self.hotspot_frac,
        )

    def to_doc(self) -> dict:
        doc = self._sweep().to_doc()
        doc["kind"] = "policy_compare"
        doc["policies"] = list(self.policies)
        doc["vcs"] = list(self.vcs)
        doc["vc_select"] = self.vc_select
        return doc

    @classmethod
    def from_doc(cls, doc: dict) -> "PolicyCompareJob":
        sweep = SweepJob.from_doc(dict(doc, kind="sweep"))
        return cls(
            mesh=sweep.mesh, pattern=sweep.pattern, rates=sweep.rates,
            policies=tuple(doc["policies"]), vcs=tuple(doc["vcs"]),
            vc_select=doc.get("vc_select", "packet"),
            nbytes=sweep.nbytes, packets_per_node=sweep.packets_per_node,
            seed=sweep.seed, params=sweep.params, engine=sweep.engine,
            hotspot=sweep.hotspot, hotspot_frac=sweep.hotspot_frac,
        )

    def fingerprint(self) -> str:
        return digest(self.to_doc())

    def workloads(self) -> list[WorkloadPoints]:
        """One workload per (policy, VC) row, policy-major — the exact
        row order of ``compare_policies``."""
        base = self.params or NoCParams()
        sweep = self._sweep()
        out = []
        for policy in self.policies:
            for num_vcs in self.vcs:
                p = dataclasses.replace(
                    base, routing=policy, num_vcs=num_vcs,
                    vc_select=self.vc_select)
                doc = sweep._population_doc(params=p)
                out.append(WorkloadPoints(
                    doc=doc, fingerprint=digest(doc), tokens=self.rates,
                    meta={"policy": policy, "num_vcs": num_vcs}))
        return out


@dataclasses.dataclass(frozen=True)
class RunProgramJob:
    """Declarative program execution (see
    :func:`~repro.core.noc.program.run_program`)."""

    program: dict                     # schema-v3 program document
    params: Optional[NoCParams] = None
    mode: str = "op"
    engine: str = "heap"
    max_cycles: int = 50_000_000

    kind = "run_program"

    @classmethod
    def of(cls, prog, params: Optional[NoCParams] = None, mode: str = "op",
           engine: str = "heap", max_cycles: int = 50_000_000):
        """Build from a live :class:`~repro.core.noc.program.Program`."""
        return cls(program=json.loads(prog.to_json()), params=params,
                   mode=mode, engine=engine, max_cycles=max_cycles)

    def to_doc(self) -> dict:
        return {
            "kind": "run_program",
            "program": self.program,
            "params": params_doc(self.params),
            "mode": self.mode,
            "engine": self.engine,
            "max_cycles": self.max_cycles,
        }

    @classmethod
    def from_doc(cls, doc: dict) -> "RunProgramJob":
        return cls(
            program=doc["program"],
            params=params_from_doc(doc["params"])
            if doc.get("params") is not None else None,
            mode=doc.get("mode", "op"),
            engine=doc.get("engine", "heap"),
            max_cycles=doc.get("max_cycles", 50_000_000),
        )

    def fingerprint(self) -> str:
        return digest(self.to_doc())

    def workloads(self) -> list[WorkloadPoints]:
        doc = self.to_doc()
        return [WorkloadPoints(doc=doc, fingerprint=digest(doc),
                               tokens=(PROGRAM_TOKEN,), meta={})]


def job_from_doc(doc: dict):
    """Parse a submitted job document; raises ``ValueError`` on an
    unknown kind or malformed fields."""
    kind = doc.get("kind")
    if kind == "sweep":
        return SweepJob.from_doc(doc)
    if kind == "policy_compare":
        return PolicyCompareJob.from_doc(doc)
    if kind == "run_program":
        return RunProgramJob.from_doc(doc)
    raise ValueError(f"unknown job kind {kind!r}; one of {JOB_KINDS}")


# ---------------------------------------------------------------------------
# Execution: the one path every chunk takes (workers, degraded in-process
# mode and tests alike).
# ---------------------------------------------------------------------------


def _sweep_artifacts(doc: dict, first_rate: float):
    """Compile the rate-independent artifacts of a sweep workload: the
    seeded population and its compiled workload.  Bit-identity with the
    direct sweep does not depend on ``first_rate`` — compiled stream
    specs are start-independent (the PR 5 compile-once invariant)."""
    from repro.core.noc.program import compile_workload, from_trace
    from repro.core.noc.traffic.patterns import (
        SyntheticConfig,
        synthetic_population,
    )
    from repro.core.topology import Mesh2D

    mesh = Mesh2D(*doc["mesh"])
    params = params_from_doc(doc["params"])
    cfg = SyntheticConfig(
        pattern=doc["pattern"], rate=first_rate, nbytes=doc["nbytes"],
        packets_per_node=doc["packets_per_node"], seed=doc["seed"],
        hotspot=tuple(doc["hotspot"]), hotspot_frac=doc["hotspot_frac"],
    )
    pop = synthetic_population(mesh, cfg)
    compiled = compile_workload(from_trace(pop.trace_at(cfg.rate)),
                                params=params)
    return mesh, params, pop, compiled


def execute_workload(doc: dict, tokens, cache) -> list:
    """Evaluate ``tokens`` on workload ``doc``; returns one JSON-ready
    row per token, in token order.

    ``cache`` is the executing process's :class:`~.cache.CompileCache`;
    sweep workloads cache their (population, CompiledWorkload) pair
    under the workload fingerprint.  Rows are exactly what the direct
    APIs produce (``SweepPoint`` asdict / per-op cycles), so memoized,
    fanned-out and serial results are bit-identical by construction.
    """
    kind = doc.get("kind")
    if kind == "sweep":
        from repro.core.noc.traffic.patterns import SyntheticConfig
        from repro.core.noc.traffic.sweep import measure

        fp = digest(doc)
        mesh, params, pop, compiled = cache.get(
            fp, lambda: _sweep_artifacts(doc, float(tokens[0])))
        rows = []
        for rate in tokens:
            cfg = SyntheticConfig(
                pattern=doc["pattern"], rate=float(rate),
                nbytes=doc["nbytes"],
                packets_per_node=doc["packets_per_node"], seed=doc["seed"],
                hotspot=tuple(doc["hotspot"]),
                hotspot_frac=doc["hotspot_frac"],
            )
            pt = measure(mesh, cfg, params=params, engine=doc["engine"],
                         compiled=compiled, population=pop)
            rows.append(dataclasses.asdict(pt))
        return rows
    if kind == "run_program":
        from repro.core.noc.program import run_program
        from repro.core.noc.program.ops import Program

        prog = Program.from_json(json.dumps(doc["program"]))
        params = params_from_doc(doc["params"])
        res = run_program(prog, params, mode=doc["mode"],
                          engine=doc["engine"],
                          max_cycles=doc["max_cycles"])
        row = {
            "makespan": res.makespan,
            "phase_end": list(res.phase_end),
            "runs": [[r.op.id, r.inject_cycle, r.done_cycle]
                     for r in res.runs],
        }
        return [row for _ in tokens]
    raise ValueError(f"cannot execute workload kind {kind!r}")
