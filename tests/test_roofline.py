"""Roofline extraction: HLO collective parsing + term arithmetic."""

import pytest

from repro.launch.roofline import Roofline, collective_bytes, model_flops_estimate

HLO_SAMPLE = """
  %all-gather = f32[512,1024]{0,1} all-gather(%copy), channel_id=1, replica_groups=[2,4]<=[8]
  %ar = bf16[1024]{0} all-reduce(%x), channel_id=2, to_apply=%add
  %rs = f32[64,32]{1,0} reduce-scatter(%y), dimensions={0}
  %cp = bf16[128,128]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %ag2 = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-gather-start(%w), channel_id=3
  %ag2d = f32[16,16]{1,0} all-gather-done(%ag2)
  %a2a = f32[8,8]{1,0} all-to-all(%v), dimensions={0}
  %meta = f32[4]{0} add(%a, %b), metadata={op_name="jit(f)/all_gather_fake"}
"""


def test_collective_bytes_parses_every_kind_once():
    out = collective_bytes(HLO_SAMPLE)
    assert out["all-gather"] == 512 * 1024 * 4 + 16 * 16 * 4  # sync + start only
    assert out["all-reduce"] == 1024 * 2
    assert out["reduce-scatter"] == 64 * 32 * 4
    assert out["collective-permute"] == 128 * 128 * 2
    assert out["all-to-all"] == 8 * 8 * 4
    # metadata mentions must not be counted
    assert sum(out.values()) < 512 * 1024 * 4 * 2


def test_roofline_terms_and_bottleneck():
    r = Roofline(arch="a", shape="s", mesh="16x16", chips=256,
                 hlo_flops=197e12 * 256,          # exactly 1s of compute
                 hlo_bytes=819e9 * 256 * 2,       # 2s of memory
                 coll_bytes=50e9 * 256 * 0.5,     # 0.5s of collectives
                 coll_breakdown={}, model_flops=197e12 * 256 * 0.8,
                 bytes_per_device=1e9)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.roofline_fraction == pytest.approx(0.5)
    assert r.useful_flops_ratio == pytest.approx(0.8)


def test_model_flops_estimate_kinds():
    from repro.configs import get_config

    cfg = get_config("yi_6b")
    n = cfg.n_active_params
    assert model_flops_estimate(cfg, "train", 4096, 256) == 6.0 * n * 4096 * 256
    assert model_flops_estimate(cfg, "prefill", 32768, 32) == 2.0 * n * 32768 * 32
    assert model_flops_estimate(cfg, "decode", 32768, 128) == 2.0 * n * 128


def test_moe_active_vs_total_params():
    from repro.configs import get_config

    phi = get_config("phi3_5_moe")
    assert phi.n_params > 3 * phi.n_active_params  # 16 experts, top-2
    assert 35e9 < phi.n_params < 50e9              # "42b" class
    assert 5e9 < phi.n_active_params < 9e9         # "a6.6b" class


def test_assigned_param_counts_sane():
    from repro.configs import get_config

    for arch, lo, hi in [("yi_6b", 5e9, 7.5e9), ("qwen1_5_0_5b", 0.3e9, 0.8e9),
                         ("glm4_9b", 8e9, 11e9), ("gemma3_12b", 10e9, 14e9),
                         ("chameleon_34b", 30e9, 38e9),
                         ("recurrentgemma_2b", 2e9, 3.5e9),
                         ("rwkv6_3b", 2.5e9, 4e9)]:
        n = get_config(arch).n_params
        assert lo < n < hi, (arch, n)
