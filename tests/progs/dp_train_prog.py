"""Data-parallel training with int8-compressed grad sync + elastic re-mesh.

8 host devices: train a tiny LM data-parallel with compressed gradient
sync (error feedback), verify loss decreases and matches the uncompressed
run approximately; then simulate losing half the fleet and continue on a
re-meshed 4-device config (elastic scaling).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_smoke_config
from repro.data import SyntheticLMSource
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig
from repro.runtime.elastic import largest_pow2_mesh, reshard


def tiny_cfg():
    cfg = get_smoke_config("qwen1_5_0_5b")
    return dataclasses.replace(cfg, n_layers=2, d_model=32, n_heads=2,
                               n_kv_heads=2, head_dim=16, d_ff=64, vocab=64)


def run_dp_compressed():
    cfg = tiny_cfg()
    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    src = SyntheticLMSource(vocab=cfg.vocab, seq_len=16, global_batch=16,
                            seed=0, branching=2)
    tcfg = TrainerConfig(compress_grads=True, dp_axis="data",
                         adamw=AdamWConfig(lr=3e-3, weight_decay=0.0),
                         warmup=5, total_steps=100)
    with jax.set_mesh(mesh):
        tr = Trainer(cfg, tcfg, mesh=mesh)
        tr.fit(src, steps=40, resume=False)
    first = np.mean([m["loss"] for m in tr.metrics_log[:5]])
    last = np.mean([m["loss"] for m in tr.metrics_log[-5:]])
    assert last < first - 0.3, (first, last)

    # compressed sync tracks the uncompressed run
    tcfg_u = dataclasses.replace(tcfg, compress_grads=False, dp_axis=None)
    tr_u = Trainer(cfg, tcfg_u)
    tr_u.fit(src, steps=40, resume=False)
    last_u = np.mean([m["loss"] for m in tr_u.metrics_log[-5:]])
    assert abs(last - last_u) < 0.5, (last, last_u)
    print(f"dp compressed ok (loss {first:.3f} -> {last:.3f}, uncompressed {last_u:.3f})")


def run_elastic():
    cfg = tiny_cfg()
    from repro.models import get_family

    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    mesh8 = largest_pow2_mesh(jax.devices(), ("data", "model"), model_max=2)
    assert mesh8.devices.size == 8
    specs = jax.tree.map(lambda _: P(), params)
    params8 = reshard(params, specs, mesh8)

    # "lose" 3 devices -> largest pow2 mesh from 5 survivors is 4
    survivors = jax.devices()[:5]
    mesh4 = largest_pow2_mesh(survivors, ("data", "model"), model_max=2)
    assert mesh4.devices.size == 4
    params4 = reshard(params8, specs, mesh4)

    src = SyntheticLMSource(vocab=cfg.vocab, seq_len=8, global_batch=8, seed=0)
    batch = {k: jax.device_put(jnp.asarray(v), NamedSharding(mesh4, P("data")))
             for k, v in src.batch_at(0).items()}
    loss = jax.jit(lambda p, b: fam.loss_fn(p, b, cfg))(params4, batch)
    assert np.isfinite(float(loss))
    print("elastic ok (8 -> 4 devices, step ran)")


if __name__ == "__main__":
    run_dp_compressed()
    run_elastic()
    print("ALL OK")
