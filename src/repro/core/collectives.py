"""CollectiveConfig: schedule selection for every collective in the framework.

``schedule='native'`` is the paper's in-network (HW) path — single XLA
collectives executed by the ICI fabric.  The software schedules
('chain' / 'pipelined' / 'tree') are the paper's optimized SW baselines,
kept as selectable regressions so the HW-vs-SW comparison is reproducible
on the production mesh (benchmarks/bench_collective_hlo.py counts their
compiled collective traffic).

``choose_schedule`` applies the paper's own analytical model (Eqs 1-6) to
pick the best software schedule for a given transfer size — the
"best software implementation on a case-by-case basis" selection of
Section 4.3 — while 'native' is always preferred when in-network support
is available.
"""

from __future__ import annotations

import dataclasses

from repro.core import schedules as sched
from repro.core.noc import model as noc_model
from repro.core.noc.params import NoCParams, PAPER_MICRO


@dataclasses.dataclass(frozen=True)
class CollectiveConfig:
    schedule: str = "native"        # native | chain | pipelined | tree
    chunks: int = 4                 # k, for the pipelined schedule
    hw_collectives: bool = True     # False = force software schedules

    def resolve(self, nbytes: int | None = None, group: int = 8,
                params: NoCParams = PAPER_MICRO) -> str:
        if self.hw_collectives and self.schedule == "native":
            return "native"
        if self.schedule != "native":
            return self.schedule
        return choose_schedule(nbytes or 0, group, params)


def choose_schedule(nbytes: int, group: int, params: NoCParams = PAPER_MICRO) -> str:
    """Pick the best *software* schedule via the paper's models."""
    n = params.beats(max(1, nbytes))
    t_seq = noc_model.multicast_seq(params, n, group)
    t_tree = noc_model.multicast_tree(params, n, group)
    t_chain = noc_model.multicast_naive(params, n, group)
    best = min((t_chain, "chain"), (t_seq, "pipelined"), (t_tree, "tree"))
    return best[1]


# Re-exports: the schedule primitives themselves.
broadcast = sched.broadcast
all_reduce = sched.all_reduce
all_gather = sched.all_gather
reduce_scatter = sched.reduce_scatter
barrier = sched.barrier
SCHEDULES = sched.SCHEDULES
