"""Blocking, reconnecting client for the simulation service protocol.

:class:`ServiceClient` connects to a :class:`~.server.SimulationServer`
— over its ``AF_UNIX`` socket (``path`` is a string) or its TCP
listener (``path`` is a ``(host, port)`` tuple plus the shared
``token``) — and exposes the three job kinds as typed submit calls,
each returning a :class:`JobHandle` that streams rows as the service
completes them:

>>> with ServiceClient(server.path) as cli:
...     h = cli.submit_sweep(mesh=(8, 8), pattern="transpose",
...                          rates=[0.02, 0.05, 0.1])
...     for index, row in h.iter_rows():   # completion order
...         ...
...     points = h.sweep_points()          # rate order, SweepPoint objects

Rows are exactly the direct API's results — ``sweep_points()`` rebuilds
the :class:`~repro.core.noc.traffic.sweep.SweepPoint` dataclasses
field-identically (JSON floats round-trip exactly), and
``policy_sweeps()`` regroups a policy-compare job into the same
:class:`~repro.core.noc.traffic.sweep.PolicySweep` rows
``compare_policies`` returns.

One reader thread demultiplexes events into per-job buffers under a
condition variable; any number of jobs can be in flight concurrently on
one connection.  A job that ends in ``error`` raises
:class:`ServiceError` from whichever accessor is waiting on it — an
overload rejection as :class:`ServiceOverloaded` (carrying the server's
``retry_after_s`` hint), a wait that expires as :class:`ServiceTimeout`
(also a ``TimeoutError``, so existing handlers keep working).

Resilience (``resume=True``): connection loss — including the server
being ``kill -9``'d mid-stream — triggers reconnection with capped
exponential backoff plus jitter, and every non-terminal job is
**idempotently resubmitted** under a fresh request id bound to the same
:class:`JobHandle`.  The re-accepted job's fingerprint must match the
original (same canonical job identity ⇒ same rows); rows are keyed by
row index so re-delivered ones are skipped, and ``iter_rows`` never
yields a row twice.  Against a server restarted on the same durable
store, the resubmission costs zero duplicate compute: completed points
come back as store hits.  Events within one connection carry a
monotonic per-job ``seq`` (tracked as ``JobHandle.last_seq``).
"""

from __future__ import annotations

import json
import random
import socket
import threading
from typing import Iterator, Optional, Union

from repro.core.noc.service.jobs import (
    PolicyCompareJob,
    RunProgramJob,
    SweepJob,
)

Address = Union[str, tuple]


class ServiceError(RuntimeError):
    """The service rejected or failed a job (deterministic execution
    errors surface here, named — never as a hang or a retry loop)."""


class ServiceTimeout(ServiceError, TimeoutError):
    """A wait on the service expired.  Subclasses ``TimeoutError`` so
    callers written against the old bare-``TimeoutError`` behavior keep
    working, and ``ServiceError`` so one handler catches everything the
    client raises."""


class ServiceOverloaded(ServiceError):
    """The service refused admission (queue at bound, or draining).
    ``retry_after_s`` is the server's backlog-drain estimate."""

    def __init__(self, message: str, retry_after_s: float):
        self.retry_after_s = retry_after_s
        super().__init__(message)


class _JobState:
    __slots__ = ("req", "doc", "accepted", "rows", "terminal", "message",
                 "retry_after_s", "last_seq")

    def __init__(self, req: str, doc: dict):
        self.req = req
        self.doc = doc                        # kept for idempotent resubmit
        self.accepted: Optional[dict] = None
        self.rows: dict[int, object] = {}
        self.terminal: Optional[str] = None   # done/cancelled/error
        self.message = ""
        self.retry_after_s: Optional[float] = None
        self.last_seq = -1

    def raise_error(self) -> None:
        if self.retry_after_s is not None:
            raise ServiceOverloaded(self.message, self.retry_after_s)
        raise ServiceError(self.message)


class JobHandle:
    """One submitted job: streamed rows plus typed result accessors."""

    def __init__(self, client: "ServiceClient", state: _JobState):
        self._client = client
        self._state = state

    @property
    def rows_total(self) -> int:
        self._client._wait(lambda: self._state.accepted is not None
                           or self._state.terminal is not None)
        if self._state.accepted is None:
            self._state.message = self._state.message or "job rejected"
            self._state.raise_error()
        return self._state.accepted["rows_total"]

    @property
    def fingerprint(self) -> str:
        self.rows_total
        return self._state.accepted["fingerprint"]

    @property
    def last_seq(self) -> int:
        """Highest event sequence number seen on the current connection
        (monotonic per job per connection; restarts after a resume)."""
        return self._state.last_seq

    def iter_rows(self) -> Iterator[tuple[int, object]]:
        """Yield ``(index, row)`` pairs in completion order — streaming:
        rows of finished chunks arrive while others still simulate.
        Rows re-delivered after a resume are skipped (row indices are
        the idempotency key), so every index is yielded exactly once."""
        yielded: set = set()
        st = self._state
        while True:
            self._client._wait(
                lambda: len(st.rows) > len(yielded) or st.terminal is not None)
            with self._client._cond:
                # dict insertion order == completion order.
                pairs = [(k, row) for k, row in st.rows.items()
                         if k not in yielded]
                terminal, message = st.terminal, st.message
            for k, row in pairs:
                yield (k, row)
                yielded.add(k)
            if terminal is not None and not pairs:
                if terminal == "error":
                    st.raise_error()
                return

    def collect(self) -> list:
        """All rows, in row-index order (rate order / policy-major
        order).  Blocks until the job is done; raises on error or
        cancellation."""
        st = self._state
        self._client._wait(lambda: st.terminal is not None)
        if st.terminal == "error":
            st.raise_error()
        if st.terminal == "cancelled":
            raise ServiceError("job was cancelled")
        return [st.rows[i] for i in range(st.accepted["rows_total"])]

    def sweep_points(self) -> list:
        """Rows rebuilt as :class:`SweepPoint` dataclasses (rate order),
        field-identical to a direct ``saturation_sweep`` call."""
        from repro.core.noc.traffic.sweep import SweepPoint

        return [SweepPoint(**row) for row in self.collect()]

    def policy_sweeps(self, knee: float = 3.0) -> list:
        """A policy-compare job's rows regrouped into
        :class:`PolicySweep` rows, identical to ``compare_policies``."""
        from repro.core.noc.traffic.sweep import (
            PolicySweep,
            SweepPoint,
            saturation_rate,
        )

        rows = self.collect()
        out = []
        for g in self._state.accepted["groups"]:
            pts = tuple(SweepPoint(**row)
                        for row in rows[g["start"]:g["start"] + g["count"]])
            out.append(PolicySweep(
                policy=g["meta"]["policy"], num_vcs=g["meta"]["num_vcs"],
                points=pts, saturation=saturation_rate(pts, knee=knee)))
        return out

    def result(self) -> dict:
        """A run-program job's single result row (makespan, phase_end,
        per-op [id, inject, done] cycles)."""
        return self.collect()[0]

    def cancel(self) -> None:
        self._client._send({"op": "cancel", "req": self._state.req})

    def wait(self, timeout: Optional[float] = None) -> str:
        """Block until terminal; returns ``"done"`` / ``"cancelled"`` /
        ``"error"``.  Raises :class:`ServiceTimeout` — never hangs past
        ``timeout`` (or the client's default read timeout)."""
        self._client._wait(lambda: self._state.terminal is not None,
                           timeout=timeout)
        if self._state.terminal is None:
            raise ServiceTimeout(f"job {self._state.req} still running")
        return self._state.terminal


class ServiceClient:
    """One connection to a :class:`SimulationServer`.

    ``path`` addresses the server: a string is an ``AF_UNIX`` socket
    path; a ``(host, port)`` tuple is the TCP listener, which requires
    the shared ``token`` (the client authenticates before anything
    else; a wrong token fails fast with :class:`ServiceError`, it is
    never retried).

    ``connect_timeout`` bounds connection establishment (including the
    auth handshake); ``timeout`` is the default read timeout of every
    blocking accessor — both default on, so a dead server is an
    exception, not a hang.  ``resume=True`` enables reconnection with
    capped exponential backoff and idempotent resubmission of in-flight
    jobs (module docstring); ``max_retries`` bounds the attempts per
    outage.
    """

    def __init__(self, path: Address, timeout: float = 300.0,
                 token: Optional[str] = None, connect_timeout: float = 10.0,
                 resume: bool = False, max_retries: int = 5,
                 backoff_base_s: float = 0.05, backoff_cap_s: float = 2.0):
        self.address = path
        self.token = token
        self.timeout = timeout
        self.connect_timeout = connect_timeout
        self.resume = resume
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self._rng = random.Random(f"service-client:{path!r}")
        if isinstance(path, tuple) and not token:
            raise ValueError("a TCP address requires the server's shared "
                             "token (token=...)")
        self._wlock = threading.Lock()
        self._cond = threading.Condition()
        self._jobs: dict[str, _JobState] = {}
        self._stats: dict[str, dict] = {}
        self._seq = 0
        self._closed = False
        self._rbuf = b""
        # resume=True retries the *initial* connect too (a resilient
        # client may legitimately start before its server).
        self._sock = (self._connect_with_backoff() if resume
                      else self._connect_once())
        self._reader = threading.Thread(
            target=self._read_loop, name="service-client", daemon=True)
        self._reader.start()

    # -- connection establishment ------------------------------------------

    def _connect_once(self):
        """One connection attempt: dial, then (TCP) authenticate —
        refused auth is terminal, never retried."""
        if isinstance(self.address, tuple):
            sock = socket.create_connection(
                self.address, timeout=self.connect_timeout)
        else:
            sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            sock.settimeout(self.connect_timeout)
            sock.connect(self.address)
        try:
            if isinstance(self.address, tuple):
                sock.sendall((json.dumps(
                    {"op": "auth", "token": self.token}) + "\n").encode())
                reply = json.loads(self._recv_line(sock))
                if reply.get("event") != "auth_ok":
                    raise ServiceError(
                        reply.get("message", "authentication refused"))
            sock.settimeout(None)
            return sock
        except BaseException:
            sock.close()
            raise

    def _connect_with_backoff(self):
        """Dial with capped exponential backoff plus jitter.  Auth
        refusal propagates immediately (retrying a bad token is a
        reconnect storm, not resilience)."""
        import time

        last: Optional[Exception] = None
        for attempt in range(self.max_retries + 1):
            if self._closed:
                raise ServiceError("client is closed")
            try:
                return self._connect_once()
            except ServiceError:
                raise
            except (OSError, json.JSONDecodeError, ValueError) as exc:
                last = exc
                if attempt == self.max_retries:
                    break
                delay = min(self.backoff_cap_s,
                            self.backoff_base_s * (2 ** attempt))
                time.sleep(delay * (0.5 + 0.5 * self._rng.random()))
        raise ServiceError(
            f"could not connect to {self.address!r} after "
            f"{self.max_retries + 1} attempt(s): {last!r}")

    def _recv_line(self, sock) -> bytes:
        """Read one ``\\n``-terminated line (handshake phase); bytes
        beyond the newline are kept for the reader loop."""
        buf = self._rbuf
        while b"\n" not in buf:
            data = sock.recv(65536)
            if not data:
                raise ServiceError("connection closed during handshake")
            buf += data
        line, self._rbuf = buf.split(b"\n", 1)
        return line

    # -- submissions -------------------------------------------------------

    def submit_job(self, doc: dict) -> JobHandle:
        """Submit a raw job document (see :mod:`~.jobs`)."""
        with self._cond:
            self._seq += 1
            req = f"r{self._seq}"
            state = _JobState(req, doc)
            self._jobs[req] = state
        self._send({"op": "submit", "req": req, "job": doc})
        return JobHandle(self, state)

    def submit_sweep(self, **kw) -> JobHandle:
        """Submit a saturation sweep (``SweepJob`` fields as kwargs)."""
        return self.submit_job(SweepJob(**kw).to_doc())

    def submit_policy_compare(self, **kw) -> JobHandle:
        """Submit a (policy x VC) comparison (``PolicyCompareJob``
        fields as kwargs)."""
        return self.submit_job(PolicyCompareJob(**kw).to_doc())

    def submit_program(self, prog, **kw) -> JobHandle:
        """Submit a program execution: ``prog`` is a live
        :class:`~repro.core.noc.program.Program` (``RunProgramJob``
        fields as kwargs)."""
        return self.submit_job(RunProgramJob.of(prog, **kw).to_doc())

    def stats(self) -> dict:
        """The scheduler's point-exact service counters."""
        with self._cond:
            self._seq += 1
            req = f"r{self._seq}"
        self._send({"op": "stats", "req": req})
        self._wait(lambda: req in self._stats)
        with self._cond:
            return self._stats.pop(req)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        self._reader.join(timeout=5)
        with self._cond:
            self._cond.notify_all()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- wire --------------------------------------------------------------

    def _send(self, doc: dict) -> None:
        if self._closed:
            raise ServiceError("client is closed")
        try:
            with self._wlock:
                self._sock.sendall((json.dumps(doc) + "\n").encode())
        except OSError as exc:
            raise ServiceError(f"connection lost while sending: {exc}")

    def _wait(self, predicate, timeout: Optional[float] = None) -> None:
        deadline = timeout if timeout is not None else self.timeout
        with self._cond:
            if not self._cond.wait_for(
                    lambda: predicate() or self._closed, timeout=deadline):
                raise ServiceTimeout(
                    f"service reply not received within {deadline:g}s")
            if self._closed and not predicate():
                raise ServiceError("connection closed while waiting")

    # -- reader / resume ---------------------------------------------------

    def _read_loop(self) -> None:
        while True:
            buf, self._rbuf = self._rbuf, b""
            sock = self._sock
            while True:
                try:
                    data = sock.recv(65536)
                except OSError:
                    data = b""
                if not data:
                    break
                buf += data
                while b"\n" in buf:
                    line, buf = buf.split(b"\n", 1)
                    if line.strip():
                        self._dispatch(json.loads(line))
            if self._closed or not self.resume:
                break
            if not self._resume_connection():
                break
        with self._cond:
            self._closed = True
            for st in self._jobs.values():
                if st.terminal is None:
                    st.terminal = "error"
                    st.message = "connection closed"
            self._cond.notify_all()

    def _resume_connection(self) -> bool:
        """Reconnect after an unexpected disconnect and idempotently
        resubmit every non-terminal job under a fresh request id bound
        to the same state (same canonical doc ⇒ same fingerprint ⇒ same
        rows; indices dedupe re-deliveries).  Returns False when the
        outage outlasts the retry budget (jobs then fail visibly)."""
        with self._cond:
            live = [st for st in self._jobs.values() if st.terminal is None]
        try:
            sock = self._connect_with_backoff()
        except ServiceError:
            return False
        with self._cond:
            remapped = {}
            for st in live:
                self._seq += 1
                st.req = f"r{self._seq}"
                st.last_seq = -1
                remapped[st.req] = st
            # Terminal states stay findable under their old reqs; live
            # ones move to their resubmission reqs.
            for req in [r for r, s in self._jobs.items() if s in live]:
                del self._jobs[req]
            self._jobs.update(remapped)
            self._sock = sock
        for st in live:
            try:
                self._send({"op": "submit", "req": st.req, "job": st.doc})
            except ServiceError:
                return True       # reader will see the drop and loop again
        return True

    def _dispatch(self, msg: dict) -> None:
        event = msg.get("event")
        req = msg.get("req")
        with self._cond:
            if event == "stats":
                self._stats[req] = msg["stats"]
                self._cond.notify_all()
                return
            st = self._jobs.get(req)
            if st is None:
                if event == "error":   # rejection of an unknown/bad req
                    pass
                self._cond.notify_all()
                return
            if "seq" in msg:
                st.last_seq = max(st.last_seq, msg["seq"])
            if event == "accepted":
                if st.accepted is None:
                    st.accepted = msg
                elif msg["fingerprint"] != st.accepted["fingerprint"]:
                    # A resumed job must be the *same* job: the canonical
                    # fingerprint is the idempotency contract.
                    st.terminal = "error"
                    st.message = ("resumed job fingerprint mismatch: "
                                  f"{msg['fingerprint']} != "
                                  f"{st.accepted['fingerprint']}")
            elif event == "rows":
                for idx, row in msg["rows"]:
                    st.rows[idx] = row
            elif event in ("done", "cancelled"):
                st.terminal = event
            elif event == "error":
                st.terminal = "error"
                st.message = msg.get("message", "service error")
                if msg.get("overloaded"):
                    st.retry_after_s = msg.get("retry_after_s", 1.0)
            elif event == "cancel_noop":
                pass
            self._cond.notify_all()
