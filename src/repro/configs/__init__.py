"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full assigned configuration;
``get_smoke_config(arch_id)`` returns a reduced same-family configuration
for CPU smoke tests (small layers/width, few experts, tiny vocab).
"""

from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "phi3_5_moe",
    "moonshot_v1_16b",
    "yi_6b",
    "qwen1_5_0_5b",
    "glm4_9b",
    "gemma3_12b",
    "chameleon_34b",
    "whisper_base",
    "recurrentgemma_2b",
    "rwkv6_3b",
]

# canonical external names -> module ids
ALIASES = {
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b",
    "yi-6b": "yi_6b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "glm4-9b": "glm4_9b",
    "gemma3-12b": "gemma3_12b",
    "chameleon-34b": "chameleon_34b",
    "whisper-base": "whisper_base",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "rwkv6-3b": "rwkv6_3b",
}


def _module(arch_id: str):
    arch_id = ALIASES.get(arch_id, arch_id)
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{arch_id}")


def get_config(arch_id: str):
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str):
    return _module(arch_id).smoke_config()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
