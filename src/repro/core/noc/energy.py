"""Energy model for the GEMM workloads (Section 4.3.3, Table 1, Fig. 10).

Primitive energies are the paper's gate-level-measured values.  The
byte/op counts are derived from first principles from the SUMMA and
FusedConcatLinear dataflows (Figures 8a/8b) and reproduce Table 1 at the
16x16 mesh:

SUMMA, mesh s, tile t, dtype 8 B, per steady-state iteration,
``n = t*t*8`` bytes per tile:
  * L2 loads: A row tiles + B column tiles fetched once each: ``2*s*n``
    (66 kB at s=16, t=16 — Table 1).
  * SW stores: the naive-sequential multicast issues one DMA store per
    receiving cluster: ``2*s*(s-1)*n`` = 983 kB.  HW: one multicast store
    stream per row/column: ``2*s*n`` = 66 kB  (Table 1 mark 1).
  * hops: SW neighbour chain + 2-hop initial fetch: ``2*s*(s+1)*n``
    = 1114 kB; HW stream crosses s-1 links per row: ``2*s*(s-1)*n`` = 983 kB.
  * SPM writes: every receiving cluster writes both tiles:
    ``2*s*(s-1)*n`` = 983 kB.
  * GEMM MACs: ``s*s*t^3`` = 1049 kOP.

FCL (one head per cluster, partial C of ``n`` bytes per cluster reduced
across the mesh toward a central tile):
  * loads/stores: each cluster loads operands and sends its partial once:
    ``s*s*n`` = 524 kB.
  * SW hops: tree reduction, average Manhattan distance to the central
    tile ~ ``s/2`` per partial (4524 kB at s=16 incl. detours, captured
    with a calibrated 1.079 factor); HW: join-tree edges only (0.9375).
  * SW reduce ops: ``(s*s-1)*t*t`` = 65 kOP, on cores (22.4 pJ/OP);
    HW: same op count via DCA (19.0 pJ/OP) — Table 1 mark 3.
  * SPM writes: SW writes every intermediate result (``(s*s-1)*n`` =
    522 kB); HW only the final column partials (``s*n`` = 35 kB) — mark 2.

An idle-energy term (clusters stalled while communication is on the
critical path, measured through the Section 4.2/4.3 runtime models)
captures the growth of the savings with mesh size (Fig. 10: up to 1.17x
for SUMMA at 256x256 and 1.13x for FCL).
"""

from __future__ import annotations

import dataclasses

from repro.core.noc.params import NoCParams, PAPER_GEMM
from repro.core.noc import model as noc_model


@dataclasses.dataclass(frozen=True)
class EnergyPrimitives:
    """Table 1 primitive energies (TSMC 7 nm, TT corner, 1 GHz)."""

    dma_load_pj_per_b: float = 2.2
    dma_store_pj_per_b: float = 2.4
    hop_pj_per_b: float = 1.1
    spm_write_pj_per_b: float = 1.8
    gemm_pj_per_op: float = 24.6
    sw_reduce_pj_per_op: float = 22.4
    dca_reduce_pj_per_op: float = 19.0
    # Idle power of a stalled cluster tile [pJ/cycle]; calibrated so the
    # Fig. 10 savings reach ~1.17x (SUMMA) / ~1.13x (FCL) at 256x256.
    idle_pj_per_cycle: float = 6.0


PRIMS = EnergyPrimitives()


@dataclasses.dataclass(frozen=True)
class Counts:
    """Bytes [B] and ops [OP] per steady-state iteration, whole mesh."""

    dma_load_b: float
    dma_store_b: float
    hop_b: float
    spm_write_b: float
    gemm_op: float
    sw_reduce_op: float = 0.0
    dca_reduce_op: float = 0.0
    idle_cluster_cycles: float = 0.0

    def energy_pj(self, prims: EnergyPrimitives = PRIMS) -> float:
        return (
            self.dma_load_b * prims.dma_load_pj_per_b
            + self.dma_store_b * prims.dma_store_pj_per_b
            + self.hop_b * prims.hop_pj_per_b
            + self.spm_write_b * prims.spm_write_pj_per_b
            + self.gemm_op * prims.gemm_pj_per_op
            + self.sw_reduce_op * prims.sw_reduce_pj_per_op
            + self.dca_reduce_op * prims.dca_reduce_pj_per_op
            + self.idle_cluster_cycles * prims.idle_pj_per_cycle
        )


def summa_counts(s: int, tile: int = 16, hw: bool = False, p: NoCParams = PAPER_GEMM) -> Counts:
    n = tile * tile * 8  # bytes per tile (fp64)
    pt = noc_model.summa_point(p, s, tile)
    if hw:
        counts = Counts(
            dma_load_b=2 * s * n,
            dma_store_b=2 * s * n,
            hop_b=2 * s * (s - 1) * n,
            spm_write_b=2 * s * (s - 1) * n,
            gemm_op=s * s * tile**3,
        )
        stall = max(0.0, pt.t_comm_hw - pt.t_comp)
    else:
        counts = Counts(
            dma_load_b=2 * s * n,
            dma_store_b=2 * s * (s - 1) * n,
            hop_b=2 * s * (s + 1) * n,
            spm_write_b=2 * s * (s - 1) * n,
            gemm_op=s * s * tile**3,
        )
        stall = max(0.0, pt.t_comm_sw - pt.t_comp)
    return dataclasses.replace(counts, idle_cluster_cycles=stall * s * s)


def fcl_counts(s: int, tile: int = 16, hw: bool = False, p: NoCParams = PAPER_GEMM) -> Counts:
    n = tile * tile * 8
    t_comp = (tile**3) / (p.gemm_utilization * p.macs_per_cycle)
    red_ops = (s * s - 1) * tile * tile
    if hw:
        red = noc_model.reduction_hw(p, p.beats(n), s, r=s if s > 1 else 1)
        counts = Counts(
            dma_load_b=s * s * n,
            dma_store_b=(2 * s + 1) * n,
            hop_b=s * s * n * (s / 2.0) * 0.9375,
            spm_write_b=s * n,
            gemm_op=s * s * tile**3,
            dca_reduce_op=red_ops,
        )
    else:
        red = noc_model.reduction_sw_best(p, p.beats(n), s, r=s if s > 1 else 1)
        counts = Counts(
            dma_load_b=s * s * n,
            dma_store_b=s * s * n,
            hop_b=s * s * n * (s / 2.0) * 1.079,
            spm_write_b=(s * s - 1) * n,
            gemm_op=s * s * tile**3,
            sw_reduce_op=red_ops,
        )
    # Reduction strictly follows compute (footnote 8): all clusters idle
    # during the reduction phase except the reducers.
    return dataclasses.replace(counts, idle_cluster_cycles=red * s * s * (0.0 if hw else 1.0))


def summa_saving(s: int, tile: int = 16, p: NoCParams = PAPER_GEMM) -> float:
    return summa_counts(s, tile, hw=False, p=p).energy_pj() / summa_counts(
        s, tile, hw=True, p=p
    ).energy_pj()


def fcl_saving(s: int, tile: int = 16, p: NoCParams = PAPER_GEMM) -> float:
    return fcl_counts(s, tile, hw=False, p=p).energy_pj() / fcl_counts(
        s, tile, hw=True, p=p
    ).energy_pj()


def table1(s: int = 16, tile: int = 16) -> dict[str, dict[str, float]]:
    """Reproduce Table 1 (counts in kB / kOP) at the given mesh size."""

    def row(c: Counts) -> dict[str, float]:
        return {
            "dma_load_kB": c.dma_load_b / 1e3,
            "dma_store_kB": c.dma_store_b / 1e3,
            "hop_kB": c.hop_b / 1e3,
            "spm_write_kB": c.spm_write_b / 1e3,
            "gemm_kOP": c.gemm_op / 1e3,
            "sw_reduce_kOP": c.sw_reduce_op / 1e3,
            "dca_reduce_kOP": c.dca_reduce_op / 1e3,
        }

    return {
        "SUMMA SW": row(summa_counts(s, tile, hw=False)),
        "SUMMA HW": row(summa_counts(s, tile, hw=True)),
        "FCL SW": row(fcl_counts(s, tile, hw=False)),
        "FCL HW": row(fcl_counts(s, tile, hw=True)),
    }
