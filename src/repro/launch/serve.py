"""End-to-end serving driver: batched generation over a request queue.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1_5_0_5b \
      --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import get_family
from repro.runtime.server import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_0_5b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None, help="restore params from checkpoint")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        from repro.checkpoint import CheckpointManager

        restored = CheckpointManager(args.ckpt_dir).restore(params)
        if restored:
            params = restored[0]
            print(f"restored params from step {restored[1]}")
    server = Server(cfg, params, max_len=args.prompt_len + args.max_new + 1,
                    temperature=args.temperature)
    rng = jax.random.PRNGKey(1)
    reqs = []
    for i in range(args.requests):
        rng, k = jax.random.split(rng)
        prompt = list(map(int, jax.random.randint(k, (args.prompt_len,), 0, cfg.vocab)))
        reqs.append(Request(prompt=prompt, max_new=args.max_new))
    t0 = time.perf_counter()
    done = server.serve(reqs, batch_slots=args.batch_slots)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s on {jax.device_count()} host device(s))")
    for r in done[:3]:
        print(f"  prompt={r.prompt[:4]}... -> {r.out}")


if __name__ == "__main__":
    main()
