"""Router microarchitecture: pluggable routing policies + turn models.

``policies`` — :class:`RoutingPolicy` and the four implementations:
               ``xy`` (dimension-ordered reference), ``yx`` (mirror),
               ``o1turn`` (cycle-balanced XY/YX split, two route
               classes), ``oddeven`` (Chiu's odd-even turn model with a
               deterministic load-spreading selection).  Resolve by name
               with :func:`get_policy`; ``NoCParams.routing`` selects
               the simulator-wide policy.
``turns``    — turn-model deadlock-freedom checks over the exact channel
               dependency graph a policy generates
               (:func:`deadlock_free`, :func:`min_vcs_for_deadlock_freedom`).
``trees``    — policy-generic multicast fork / reduction join tree
               builders (:func:`fork_tree`, :func:`join_tree`),
               bit-identical to the legacy XY builders for the ``xy``
               policy and memoized on (policy, mesh, addresses).

Virtual channels live in ``NoCParams`` (``num_vcs``, ``vc_map``,
``vc_select``) and in the engines' per-(link, VC) arbitration; this
package only decides *where* beats go, never *when*.
"""

from repro.core.noc.routing.policies import (  # noqa: F401
    POLICIES,
    O1TurnPolicy,
    OddEvenPolicy,
    RoutingPolicy,
    XYPolicy,
    YXPolicy,
    get_policy,
)
from repro.core.noc.routing.trees import fork_tree, join_tree  # noqa: F401
from repro.core.noc.routing.turns import (  # noqa: F401
    deadlock_free,
    has_cycle,
    min_vcs_for_deadlock_freedom,
    policy_dependencies,
)
