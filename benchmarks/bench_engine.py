"""Engine shoot-out: cycle vs event vs heap vs shard, storm + sweep.

The perf trajectory guard for the simulator hot path.  Times the
bit-identical engines on collective storms (8x8 .. 64x64), checks the
results agree, and emits ``BENCH_engine.json`` at the repo root so
future PRs have a baseline to regress against.

New rows in this revision:

* ``storm64_shard`` — engine-only walls of heap vs the region-sharded
  engine (serial region schedule and the ``workers`` process backend) on
  the 64x64 storm, with ``EngineProfile`` counters (heap churn, epochs,
  boundary reconciliations — the data region-size tuning reads).
* ``storm128`` / ``sweep128_curve`` — the first feasible 128x128 rows
  (collective storm + uniform saturation curve).  Gated behind
  ``--full128`` (or ``BENCH_ENGINE_FULL=1``) so CI stays fast; run
  nightly-style to refresh.  Both rows are interruption-safe, each at
  its natural granularity: the storm legs auto-checkpoint the paused
  sim every ``STORM128_CKPT_INTERVAL`` cycles
  (``resilience.run_with_autocheckpoint``), and the sweep journals each
  completed point — kill the nightly at any moment and the rerun
  resumes instead of restarting.
* ``sweep_compile_once`` — the same 32x32 curve with and without the
  compile-once workload cache (routes/trees/specs lowered once, only
  injection starts swapped per point).

Run standalone as a CI gate::

    PYTHONPATH=src python -m benchmarks.bench_engine --smoke

exits non-zero if the heap engine is slower than the event engine on the
16x16 storm, the shard engine's fingerprint diverges from heap's, the
shard engine is materially slower than heap on that storm, or any engine
disagrees on a makespan.

The legacy per-cycle loop is only timed where it finishes in reasonable
wall-clock; larger scenarios record ``null`` for it rather than burning
minutes re-measuring a known order of magnitude.
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

from repro.core.noc.params import PAPER_MICRO
from repro.core.noc.program import from_trace
from repro.core.noc.program.lower import add_op
from repro.core.noc.program.ops import BarrierOp
from repro.core.noc.netsim import NoCSim
from repro.core.noc.traffic import collective_storm, replay, saturation_sweep
from repro.core.topology import Mesh2D

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_engine.json"

SWEEP_RATES = (0.01, 0.05, 0.2)
# Serial region schedule: no fork/IPC overhead, still the shard engine.
SHARD_SERIAL = "shard:1x2:1"


def _time_storm(mesh_side: int, engine: str, phases: int = 2,
                tile_bytes: int = 2048) -> tuple[float, int]:
    trace = collective_storm(Mesh2D(mesh_side, mesh_side),
                             tile_bytes=tile_bytes, phases=phases)
    t0 = time.perf_counter()
    res = replay(trace, params=PAPER_MICRO, engine=engine)
    return time.perf_counter() - t0, res.makespan


def _time_sweep(mesh_side: int, engine: str, workers: int = 0) -> tuple[float, int]:
    t0 = time.perf_counter()
    pts = saturation_sweep(
        Mesh2D(mesh_side, mesh_side), "uniform", SWEEP_RATES, nbytes=256,
        packets_per_node=1, seed=0, params=PAPER_MICRO, engine=engine,
        workers=workers,
    )
    return time.perf_counter() - t0, pts[-1].makespan


# scenario -> {engine: runner or None (too slow to time)}
SCENARIOS = {
    "storm8": {e: (lambda e=e: _time_storm(8, e)) for e in ("cycle", "event", "heap")},
    "storm16": {
        e: (lambda e=e: _time_storm(16, e))
        for e in ("cycle", "event", "heap", SHARD_SERIAL)
    },
    "storm32": {
        "cycle": None,
        "event": lambda: _time_storm(32, "event", phases=1),
        "heap": lambda: _time_storm(32, "heap", phases=1),
        SHARD_SERIAL: lambda: _time_storm(32, SHARD_SERIAL, phases=1),
    },
    "sweep8": {e: (lambda e=e: _time_sweep(8, e)) for e in ("cycle", "event", "heap")},
    "sweep16": {
        "cycle": None,
        "event": lambda: _time_sweep(16, "event"),
        "heap": lambda: _time_sweep(16, "heap"),
    },
    "sweep32": {
        "cycle": None,
        "event": lambda: _time_sweep(32, "event"),
        "heap": lambda: _time_sweep(32, "heap"),
    },
}


def _run_scenarios(names=None) -> dict:
    out: dict[str, dict] = {}
    for name, engines in SCENARIOS.items():
        if names and name not in names:
            continue
        walls: dict[str, float | None] = {}
        makespans = set()
        for engine, fn in engines.items():
            if fn is None:
                walls[engine] = None
                continue
            wall, makespan = fn()
            walls[engine] = round(wall, 4)
            makespans.add(makespan)
        if len(makespans) != 1:
            raise AssertionError(
                f"{name}: engines disagree on makespan: {sorted(makespans)}"
            )
        rec = {"wall_s": walls, "makespan": makespans.pop()}
        if walls.get("cycle") and walls.get("heap"):
            rec["speedup_vs_cycle"] = round(walls["cycle"] / walls["heap"], 2)
        if walls.get("event") and walls.get("heap"):
            rec["speedup_vs_event"] = round(walls["event"] / walls["heap"], 2)
        out[name] = rec
    return out


# ---------------------------------------------------------------------------
# Engine-only storm timing (lowering excluded) with profile counters.
# ---------------------------------------------------------------------------


def _storm_engine_run(mesh_side: int, engine: str, phases: int = 2,
                      tile_bytes: int = 2048, reps: int = 2):
    """Lower the storm once per rep, then time only ``sim.run`` (summed
    over the barrier phases; best of ``reps`` — engine walls on loaded
    machines jitter far more than the engines differ) and collect the
    engine's profile counters."""
    mesh = Mesh2D(mesh_side, mesh_side)
    prog = from_trace(collective_storm(mesh, tile_bytes=tile_bytes,
                                       phases=phases))
    p = PAPER_MICRO
    by_phase: dict[int, list] = {}
    for op in prog.ops:
        by_phase.setdefault(op.phase, []).append(op)
    best = float("inf")
    for _ in range(reps):
        sim = NoCSim(mesh, p)
        offset = 0.0
        wall = 0.0
        counters: dict[str, int] = {}
        for phase in range(prog.num_phases):
            barrier_cost = 0.0
            for op in by_phase.get(phase, ()):
                if isinstance(op, BarrierOp):
                    barrier_cost = max(barrier_cost, op.cost(p))
                    continue
                add_op(sim, op, offset + op.start, p)
            t0 = time.perf_counter()
            prof = sim.run(engine=engine, profile=True)
            wall += time.perf_counter() - t0
            for k, v in prof.counters().items():
                if k in ("regions", "workers"):  # configuration, not volume
                    counters[k] = v
                else:
                    counters[k] = counters.get(k, 0) + v
            offset = max(offset, prof.makespan) + barrier_cost
        best = min(best, wall)
    return best, prof.makespan, counters


def _storm64_shard(workers: int) -> dict:
    """The acceptance row: shard vs heap engine wall on the 64x64 storm."""
    engines = {
        "heap": "heap",
        "shard_serial": SHARD_SERIAL,
        "shard_workers": f"shard::{workers}",
    }
    out: dict = {"workers": workers, "cpu_count": os.cpu_count(),
                 "wall_s": {}, "profile": {}}
    makespans = set()
    for label, engine in engines.items():
        wall, makespan, counters = _storm_engine_run(64, engine)
        out["wall_s"][label] = round(wall, 3)
        out["profile"][label] = counters
        makespans.add(makespan)
    if len(makespans) != 1:
        raise AssertionError(f"storm64: engines disagree: {sorted(makespans)}")
    out["makespan"] = makespans.pop()
    heap = out["wall_s"]["heap"]
    out["speedup_serial"] = round(heap / out["wall_s"]["shard_serial"], 2)
    out["speedup_workers"] = round(heap / out["wall_s"]["shard_workers"], 2)
    return out


# Auto-checkpoint boundary for the nightly 128x128 storm legs: coarse
# enough (relative to the cycles-per-second the engines sustain on this
# mesh) that the measured snapshot overhead stays within ~1.2x of the
# plain wall (bench_resilience measures the overhead-vs-interval curve).
STORM128_CKPT_INTERVAL = 2048


def _storm128_leg(engine: str, label: str) -> tuple:
    """One 128x128 storm leg under periodic auto-checkpointing: the run
    snapshots every ``STORM128_CKPT_INTERVAL`` cycles next to the JSON
    output, so an interrupted nightly resumes from its last boundary
    (and from zero wasted work — the checkpointed run is bit-identical,
    so the cross-engine makespan assertion still holds)."""
    from repro.core.noc.resilience import run_with_autocheckpoint

    mesh = Mesh2D(128, 128)
    prog = from_trace(collective_storm(mesh, tile_bytes=2048, phases=1))
    p = PAPER_MICRO
    sim = NoCSim(mesh, p)
    for op in prog.ops:
        if isinstance(op, BarrierOp):
            continue
        add_op(sim, op, op.start, p)
    ckpt = str(JSON_PATH.parent / f".bench_storm128.{label}.ckpt.json")
    t0 = time.perf_counter()
    sim, makespan = run_with_autocheckpoint(
        sim, ckpt, interval=STORM128_CKPT_INTERVAL, engine=engine)
    wall = time.perf_counter() - t0
    return wall, makespan


def _storm128() -> dict:
    """128x128 collective-storm feasibility: heap vs shard engine wall.

    Both legs run under ``run_with_autocheckpoint`` (one pass each — the
    resumable snapshot, like ``_sweep128``'s point journal, makes rerun
    cost bounded, so best-of-reps averaging is not worth doubling the
    nightly wall)."""
    out: dict = {"wall_s": {}, "cpu_count": os.cpu_count(),
                 "ckpt_interval": STORM128_CKPT_INTERVAL}
    makespans = set()
    for label, engine in (("heap", "heap"), ("shard", SHARD_SERIAL)):
        wall, makespan = _storm128_leg(engine, label)
        out["wall_s"][label] = round(wall, 2)
        makespans.add(makespan)
    if len(makespans) != 1:
        raise AssertionError(f"storm128: engines disagree: {sorted(makespans)}")
    out["makespan"] = makespans.pop()
    out["speedup_vs_heap"] = round(out["wall_s"]["heap"] / out["wall_s"]["shard"], 2)
    out["feasible"] = out["wall_s"]["shard"] < 120.0
    return out


def _sweep128(workers: int) -> dict:
    """128x128 uniform saturation curve (compile-once + process fan-out).

    The long-running row journals each completed point next to the JSON
    output, so an interrupted nightly run resumes instead of restarting —
    the journal is deleted once the curve lands in BENCH_engine.json.
    """
    rates = (0.005, 0.02, 0.05)
    journal = str(JSON_PATH.parent / ".bench_sweep128.journal.jsonl")
    t0 = time.perf_counter()
    pts = saturation_sweep(
        Mesh2D(128, 128), "uniform", rates, nbytes=256, packets_per_node=1,
        seed=0, params=PAPER_MICRO, engine="heap", workers=workers,
        journal=journal,
    )
    wall = time.perf_counter() - t0
    try:
        os.remove(journal)
    except OSError:
        pass
    return {
        "wall_s": round(wall, 2),
        "workers": workers,
        "points": len(pts),
        "makespans": [p.makespan for p in pts],
        "feasible": wall < 600.0,
    }


def _sweep64(workers: int) -> dict:
    rates = (0.005, 0.01, 0.02, 0.05, 0.1, 0.2)
    t0 = time.perf_counter()
    pts = saturation_sweep(
        Mesh2D(64, 64), "uniform", rates, nbytes=256, packets_per_node=1,
        seed=0, params=PAPER_MICRO, engine="heap", workers=workers,
    )
    wall = time.perf_counter() - t0
    return {
        "wall_s": round(wall, 2),
        "workers": workers,
        "points": len(pts),
        "makespans": [p.makespan for p in pts],
    }


def _clear_lowering_caches() -> None:
    """Reset the route/tree LRU memos so both sweep variants lower from a
    cold cache — what a fresh worker process actually experiences (warm
    in-process memos would otherwise hide most of the re-lowering
    cost this row exists to measure)."""
    from repro.core.topology import (
        _multicast_fork_tree_cached,
        _reduction_join_tree_cached,
        _xy_route_cached,
    )
    from repro.core.noc.routing import trees as _trees

    _xy_route_cached.cache_clear()
    _multicast_fork_tree_cached.cache_clear()
    _reduction_join_tree_cached.cache_clear()
    for fn in ("_fork_tree_cached", "_join_tree_cached"):
        cached = getattr(_trees, fn, None)
        if cached is not None and hasattr(cached, "cache_clear"):
            cached.cache_clear()


def _sweep_compile_once() -> dict:
    """Compile-once amortization: the same repeated-rate 32x32 curve with
    per-point re-lowering vs the cached CompiledWorkload."""
    mesh = Mesh2D(32, 32)
    rates = SWEEP_RATES + SWEEP_RATES  # repeated-rate sweep
    kw = dict(nbytes=256, packets_per_node=1, seed=0, params=PAPER_MICRO)
    _clear_lowering_caches()
    t0 = time.perf_counter()
    a = saturation_sweep(mesh, "uniform", rates, compile_once=False, **kw)
    t1 = time.perf_counter()
    _clear_lowering_caches()
    b = saturation_sweep(mesh, "uniform", rates, compile_once=True, **kw)
    t2 = time.perf_counter()
    if [p.makespan for p in a] != [p.makespan for p in b]:
        raise AssertionError("compile-once sweep diverged from relower path")
    return {
        "points": len(rates),
        "relower_wall_s": round(t1 - t0, 3),
        "compiled_wall_s": round(t2 - t1, 3),
        "amortization": round((t1 - t0) / max(t2 - t1, 1e-9), 2),
    }


def _load_existing() -> dict:
    """Keep rows the current invocation does not refresh (the 128x128
    rows are nightly-style: absent from a default run, preserved from the
    last ``--full128`` run)."""
    if JSON_PATH.exists():
        try:
            return json.loads(JSON_PATH.read_text())
        except (OSError, json.JSONDecodeError):
            pass
    return {}


def rows(full128: bool | None = None):
    if full128 is None:
        full128 = os.environ.get("BENCH_ENGINE_FULL", "") not in ("", "0")
    results = _load_existing()
    results.update(_run_scenarios())
    workers = min(8, os.cpu_count() or 1)
    results["sweep64_heap_curve"] = _sweep64(workers)
    results["storm64_shard"] = _storm64_shard(max(4, workers))
    results["sweep_compile_once"] = _sweep_compile_once()
    if full128:
        results["storm128"] = _storm128()
        results["sweep128_curve"] = _sweep128(workers)
    from benchmarks.run import provenance

    results["provenance"] = provenance()
    JSON_PATH.write_text(json.dumps(results, indent=2) + "\n")
    out = []
    for name, rec in results.items():
        if name == "provenance":
            continue
        if name in ("sweep64_heap_curve", "sweep128_curve"):
            out.append((name, rec["wall_s"] * 1e6,
                        f"points={rec['points']};workers={rec['workers']};"
                        f"feasible={rec.get('feasible', rec['wall_s'] < 60.0)}"))
            continue
        if name == "storm64_shard":
            out.append((name, rec["wall_s"]["shard_serial"] * 1e6,
                        f"heap={rec['wall_s']['heap']}s;"
                        f"x_serial={rec['speedup_serial']};"
                        f"x_workers{rec['workers']}={rec['speedup_workers']};"
                        f"epochs={rec['profile']['shard_serial']['epochs']}"))
            continue
        if name == "storm128":
            out.append((name, rec["wall_s"]["shard"] * 1e6,
                        f"heap={rec['wall_s']['heap']}s;"
                        f"x_heap={rec['speedup_vs_heap']};"
                        f"feasible={rec['feasible']}"))
            continue
        if name == "sweep_compile_once":
            out.append((name, rec["compiled_wall_s"] * 1e6,
                        f"relower={rec['relower_wall_s']}s;"
                        f"amortization=x{rec['amortization']}"))
            continue
        walls = rec["wall_s"]
        detail = ";".join(
            f"{e}={w:.3f}s" if w is not None else f"{e}=skipped"
            for e, w in walls.items()
        )
        for k in ("speedup_vs_cycle", "speedup_vs_event"):
            if k in rec:
                detail += f";{k.replace('speedup_vs_', 'x_')}={rec[k]}"
        out.append((name, (walls.get("heap") or 0.0) * 1e6, detail))
    return out


def smoke() -> int:
    """CI gate: heap must not lag event, and the shard engine must be
    fingerprint-identical to heap (and not materially slower) on the
    16x16 storm."""
    results = _run_scenarios(names={"storm16"})
    rec = results["storm16"]
    print(json.dumps(rec, indent=2))
    if rec["wall_s"]["heap"] > rec["wall_s"]["event"]:
        print("FAIL: heap engine slower than event engine on storm16")
        return 1
    # Shard gate: bit-identical stream completions + competitive wall.
    trace = collective_storm(Mesh2D(16, 16), tile_bytes=2048, phases=2)
    ref = replay(trace, params=PAPER_MICRO, engine="heap")
    got = replay(trace, params=PAPER_MICRO, engine=SHARD_SERIAL)
    if ([s.done_cycle for s in ref.streams] != [s.done_cycle for s in got.streams]
            or ref.makespan != got.makespan):
        print("FAIL: shard engine fingerprint diverges from heap on storm16")
        return 1
    shard_wall = rec["wall_s"][SHARD_SERIAL]
    if shard_wall > rec["wall_s"]["heap"] * 1.25:
        print(f"FAIL: shard engine materially slower than heap on storm16 "
              f"({shard_wall}s vs {rec['wall_s']['heap']}s)")
        return 1
    print(f"OK: heap {rec['speedup_vs_event']}x faster than event, "
          f"{rec['speedup_vs_cycle']}x faster than cycle; shard "
          f"fingerprint-identical at {shard_wall}s")
    return 0


if __name__ == "__main__":
    import sys

    if "--smoke" in sys.argv:
        sys.exit(smoke())
    for name, us, derived in rows(full128="--full128" in sys.argv or None):
        print(f"{name},{us},{derived}")
