"""SUMMA + FusedConcatLinear on real (host) devices with every schedule,
plus the NoC cost path of the same workload as a collective program.

Run with multiple host devices:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/distributed_gemm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fcl import fcl_sharded
from repro.core.overlap import ag_matmul_sharded, matmul_rs_sharded
from repro.core.summa import summa_sharded


def noc_cost_path():
    """The canonical program-API usage: the fabric+compute workload of a
    double-buffered SUMMA run, executed under contention in one pass."""
    from repro.core.noc.params import PAPER_MICRO
    from repro.core.noc.program import run_program
    from repro.core.summa import summa_program
    from repro.core.topology import Mesh2D

    print("NoC cost path: 8x8 SUMMA program with per-tile ComputeOps")
    prog = summa_program(Mesh2D(8, 8), tile_bytes=2048, schedule="native",
                         iters=4, compute_cycles="model")
    overlapped = run_program(prog, PAPER_MICRO, mode="op")
    serialized = run_program(prog, PAPER_MICRO, mode="barrier")
    comm = run_program(prog.comm_only(), PAPER_MICRO, mode="op")
    comp = run_program(prog.compute_only(), PAPER_MICRO, mode="op")
    print(f"  per-op gated (comm/compute overlap): {overlapped.makespan} cycles")
    print(f"  barrier-serialized baseline:         {serialized.makespan:.0f} cycles"
          f"  ({serialized.makespan / overlapped.makespan:.2f}x slower)")
    print(f"  comm-only {comm.makespan} / compute-only {comp.makespan} cycles"
          " (overlap lower bound)")


def main():
    noc_cost_path()
    n_dev = jax.device_count()
    print(f"{n_dev} devices")
    if n_dev >= 4:
        side = 2
        mesh = jax.make_mesh((side, side), ("row", "col"),
                             devices=jax.devices()[: side * side],
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        A = jax.random.normal(jax.random.PRNGKey(0), (512, 512), jnp.float32)
        B = jax.random.normal(jax.random.PRNGKey(1), (512, 512), jnp.float32)
        ref = np.asarray(A @ B)
        print("\nSUMMA GEMM (512^3) on a 2x2 grid:")
        for sched in ("native", "chain", "pipelined", "tree", "ring"):
            with jax.set_mesh(mesh):
                fn = jax.jit(lambda a, b, s=sched: summa_sharded(
                    a, b, mesh, "row", "col", schedule=s))
                C = fn(A, B)
                C.block_until_ready()
                t0 = time.perf_counter()
                for _ in range(10):
                    C = fn(A, B)
                C.block_until_ready()
                dt = (time.perf_counter() - t0) / 10
            err = np.abs(np.asarray(C) - ref).max()
            print(f"  {sched:>10}: {dt*1e6:8.1f} us  max_err={err:.2e}")

    axis_mesh = jax.make_mesh((n_dev,), ("model",),
                              axis_types=(jax.sharding.AxisType.Auto,))
    attn = jax.random.normal(jax.random.PRNGKey(2), (64, 16 * n_dev), jnp.float32)
    wo = jax.random.normal(jax.random.PRNGKey(3), (16 * n_dev, 32), jnp.float32)
    print(f"\nFusedConcatLinear reduction over {n_dev} head-shards:")
    for sched in ("native", "chain", "tree"):
        with jax.set_mesh(axis_mesh):
            y = fcl_sharded(attn, wo, axis_mesh, schedule=sched)
        err = np.abs(np.asarray(y) - np.asarray(attn @ wo)).max()
        print(f"  {sched:>10}: max_err={err:.2e}")

    print("\noverlapped collective matmuls (beyond-paper):")
    x = jax.random.normal(jax.random.PRNGKey(4), (16 * n_dev, 32), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(5), (32, 8 * n_dev), jnp.float32)
    with jax.set_mesh(axis_mesh):
        y = ag_matmul_sharded(x, w, axis_mesh)
    print(f"  ag_matmul   max_err={np.abs(np.asarray(y) - np.asarray(x @ w)).max():.2e}")
    x2 = jax.random.normal(jax.random.PRNGKey(6), (16 * n_dev, 32 * n_dev), jnp.float32)
    w2 = jax.random.normal(jax.random.PRNGKey(7), (32 * n_dev, 24), jnp.float32)
    with jax.set_mesh(axis_mesh):
        y2 = matmul_rs_sharded(x2, w2, axis_mesh)
    print(f"  matmul_rs   max_err={np.abs(np.asarray(y2) - np.asarray(x2 @ w2)).max():.2e}")


if __name__ == "__main__":
    main()
