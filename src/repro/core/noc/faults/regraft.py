"""Collective-tree re-grafting around faulted nodes and links.

Degraded multicast fork trees and reduction join trees are rebuilt with
exactly the grafting discipline of ``routing/trees.py`` — destinations
visited in sorted order and grafted at the **deepest** already-in-tree
node of their route (fork), sources walked toward the root and grafted
at the **first** already-in-tree node (join) — which preserves the tree
validity invariants the simulator's lockstep beat expansion depends on:
every fork-tree node has exactly one parent (an out-tree, every
destination locally delivered), every join-tree node except the root
forwards to exactly one output (an in-tree, every source locally
contributed).

Per-leg routes come from the base policy when its ``tree_route`` /
``join_route`` is fully healthy, else from the plain-BFS
:func:`~repro.core.noc.faults.repair.healthy_path` (shortest healthy
path, no turn constraints — collective trees are the lockstep mechanism
excluded from the unicast escape-VC deadlock argument; their contract is
the validity invariants above, checked by :func:`check_fork_tree` /
:func:`check_join_tree` and the property tests).

Dead *destinations* of a multicast and dead *sources* of a reduction are
dropped from the tree (the collective completes over the survivors,
mirroring how ``runtime/elastic.py`` shrinks the device mesh); a dead
multicast source, a dead reduction root, or a live-but-partitioned
endpoint raises :class:`~repro.core.noc.faults.model.FaultDisconnectedError`
with the endpoint and the fault pattern.

Results are memoized on ``(policy name, mesh, addresses, faults)`` —
:class:`FaultSet` is frozen and hashable precisely so it can key these
caches — and callers receive fresh copies, like ``trees.py``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

from repro.core.noc.faults.model import FaultDisconnectedError, FaultSet
from repro.core.noc.faults.repair import healthy_path, route_is_healthy
from repro.core.noc.routing.policies import RoutingPolicy, get_policy
from repro.core.topology import Coord, Mesh2D, MultiAddress


@dataclasses.dataclass(frozen=True)
class RegraftInfo:
    """What re-grafting changed relative to the healthy tree."""

    rerouted: int = 0                       # legs that needed a healthy-BFS path
    dropped: tuple[Coord, ...] = ()         # dead endpoints removed from the tree

    @property
    def changed(self) -> bool:
        return bool(self.rerouted or self.dropped)


def _tree_leg(mesh: Mesh2D, faults: FaultSet, policy: RoutingPolicy,
              src: Coord, dst: Coord, join: bool) -> tuple[tuple[Coord, ...], bool]:
    base = (policy.join_route if join else policy.tree_route)(mesh, src, dst)
    if route_is_healthy(faults, base):
        return base, False
    return healthy_path(mesh, faults, src, dst), True


@functools.lru_cache(maxsize=4096)
def _fork_tree_degraded_cached(
    policy_name: str, mesh: Mesh2D, src: Coord, maddr: MultiAddress,
    faults: FaultSet,
) -> tuple[dict[Coord, frozenset[Coord]], RegraftInfo]:
    policy = get_policy(policy_name)
    if faults.router_is_dead(src):
        raise FaultDisconnectedError(
            f"multicast source ({src.x},{src.y}) is a dead router "
            f"({faults.describe()})")
    fork: dict[Coord, set[Coord]] = {}
    in_tree = {src}
    rerouted = 0
    dropped: list[Coord] = []
    for dst in sorted(maddr.destinations(mesh), key=tuple):
        if faults.router_is_dead(dst):
            dropped.append(dst)
            continue
        path, detoured = _tree_leg(mesh, faults, policy, src, dst, join=False)
        rerouted += detoured
        # Deepest in-tree graft, as in trees.py: everything after the
        # graft point is new, so each node acquires exactly one parent.
        start = max(i for i, n in enumerate(path) if n in in_tree)
        for a, b in zip(path[start:], path[start + 1:]):
            fork.setdefault(a, set()).add(b)
            in_tree.add(b)
        fork.setdefault(dst, set()).add(dst)  # local delivery
    if not dropped and rerouted == 0:
        # Bit-identical to the healthy tree by construction; still report
        # an unchanged RegraftInfo so callers need no special case.
        pass
    return ({k: frozenset(v) for k, v in fork.items()},
            RegraftInfo(rerouted=rerouted, dropped=tuple(dropped)))


def fork_tree_degraded(
    mesh: Mesh2D, src: Coord, maddr: MultiAddress,
    policy: RoutingPolicy | str = "xy", faults: FaultSet | None = None,
) -> tuple[dict[Coord, set[Coord]], RegraftInfo]:
    """Degraded multicast fork map ``{router: {next hops (self = local
    delivery)}}`` plus what changed.  With no (or empty) faults this is
    exactly ``trees.fork_tree``."""
    name = policy if isinstance(policy, str) else policy.name
    if faults is None or faults.empty:
        from repro.core.noc.routing.trees import fork_tree

        return fork_tree(mesh, src, maddr, policy=name), RegraftInfo()
    cached, info = _fork_tree_degraded_cached(name, mesh, src, maddr, faults)
    return {k: set(v) for k, v in cached.items()}, info


@functools.lru_cache(maxsize=4096)
def _join_tree_degraded_cached(
    policy_name: str, mesh: Mesh2D, sources: tuple[Coord, ...], dst: Coord,
    faults: FaultSet,
) -> tuple[dict[Coord, frozenset[Coord]], RegraftInfo]:
    policy = get_policy(policy_name)
    if faults.router_is_dead(dst):
        raise FaultDisconnectedError(
            f"reduction root ({dst.x},{dst.y}) is a dead router "
            f"({faults.describe()})")
    join: dict[Coord, set[Coord]] = {}
    in_tree = {dst}  # nodes that already have an output (or are the root)
    rerouted = 0
    dropped: list[Coord] = []
    for s in sources:
        if faults.router_is_dead(s):
            dropped.append(s)
            continue
        path, detoured = _tree_leg(mesh, faults, policy, s, dst, join=True)
        rerouted += detoured
        join.setdefault(s, set()).add(s)  # local contribution
        for a, b in zip(path, path[1:]):
            if a in in_tree:
                break  # flow continues along the existing tree
            join.setdefault(b, set()).add(a)
            in_tree.add(a)
    return ({k: frozenset(v) for k, v in join.items()},
            RegraftInfo(rerouted=rerouted, dropped=tuple(dropped)))


def join_tree_degraded(
    mesh: Mesh2D, sources: Sequence[Coord], dst: Coord,
    policy: RoutingPolicy | str = "xy", faults: FaultSet | None = None,
) -> tuple[dict[Coord, set[Coord]], RegraftInfo]:
    """Degraded reduction join map ``{router: {inputs (self = local
    contribution)}}`` plus what changed.  With no (or empty) faults this
    is exactly ``trees.join_tree``."""
    name = policy if isinstance(policy, str) else policy.name
    if faults is None or faults.empty:
        from repro.core.noc.routing.trees import join_tree

        return join_tree(mesh, sources, dst, policy=name), RegraftInfo()
    cached, info = _join_tree_degraded_cached(
        name, mesh, tuple(sources), dst, faults)
    return {k: set(v) for k, v in cached.items()}, info


# ---------------------------------------------------------------------------
# Validity invariants (the contract the property tests assert).
# ---------------------------------------------------------------------------


def check_fork_tree(mesh: Mesh2D, fork: dict[Coord, set[Coord]], src: Coord,
                    dests: Sequence[Coord],
                    faults: FaultSet | None = None) -> None:
    """Out-tree invariants: every non-source node has exactly one parent,
    every (live) destination is locally delivered, no edge touches a
    faulted element."""
    parents: dict[Coord, int] = {}
    for a, hops in fork.items():
        for b in hops:
            if b == a:
                continue
            parents[b] = parents.get(b, 0) + 1
            if faults is not None and faults.link_is_dead(a, b):
                raise AssertionError(
                    f"fork tree uses faulted link ({a.x},{a.y})->({b.x},{b.y})")
    bad = [n for n, k in parents.items() if k != 1]
    if bad or src in parents:
        raise AssertionError(f"fork tree is not an out-tree: {bad or [src]}")
    for d in dests:
        if faults is not None and faults.router_is_dead(d):
            if d in fork:
                raise AssertionError(f"dead destination {tuple(d)} in tree")
            continue
        if d not in fork or d not in fork[d]:
            raise AssertionError(f"destination {tuple(d)} lacks local delivery")


def check_join_tree(mesh: Mesh2D, join: dict[Coord, set[Coord]], dst: Coord,
                    sources: Sequence[Coord],
                    faults: FaultSet | None = None) -> None:
    """In-tree invariants: every router except the root forwards to
    exactly one output, every (live) source locally contributes, no edge
    touches a faulted element."""
    outputs: dict[Coord, int] = {}
    for b, inputs in join.items():
        for a in inputs:
            if a == b:
                continue
            outputs[a] = outputs.get(a, 0) + 1
            if faults is not None and faults.link_is_dead(a, b):
                raise AssertionError(
                    f"join tree uses faulted link ({a.x},{a.y})->({b.x},{b.y})")
    bad = [n for n, k in outputs.items() if k != 1]
    if bad or dst in outputs:
        raise AssertionError(f"join tree is not an in-tree: {bad or [dst]}")
    for s in sources:
        if faults is not None and faults.router_is_dead(s):
            if any(s in inputs for inputs in join.values()) or s in join:
                raise AssertionError(f"dead source {tuple(s)} in tree")
            continue
        if s not in join or s not in join[s]:
            raise AssertionError(f"source {tuple(s)} lacks local contribution")
