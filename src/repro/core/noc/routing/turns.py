"""Turn-model deadlock-freedom checks for routing policies.

A wormhole network is deadlock-free if the channel dependency graph
(CDG) — directed links as nodes, an edge wherever some packet can hold
link A while requesting link B — is acyclic (Dally & Seitz).  For a
deterministic policy the CDG is computable exactly: enumerate every
route the policy can emit on a mesh and record each consecutive link
pair as a dependency.

Policies with ``route_classes > 1`` (O1TURN) are validated per class:
each class must be acyclic on its own virtual network, while the union
may (and for O1TURN does) contain cycles — that is precisely why O1TURN
needs one VC per class, and ``min_vcs_for_deadlock_freedom`` reports it.
"""

from __future__ import annotations

from typing import Iterable, Sequence

from repro.core.topology import Coord, Mesh2D

Link = tuple[Coord, Coord]

# Canonical direction names for turn reporting.
_DIR_NAMES = {(1, 0): "E", (-1, 0): "W", (0, 1): "N", (0, -1): "S"}


def _link_dir(link: Link) -> tuple[int, int]:
    a, b = link
    return (b.x - a.x, b.y - a.y)


def route_turns(path: Sequence[Coord]) -> list[tuple[Link, Link]]:
    """Consecutive link pairs (the turns, plus straight-throughs) of a path."""
    links = list(zip(path, path[1:]))
    return list(zip(links, links[1:]))


def policy_dependencies(
    policy, mesh: Mesh2D, route_class: int | None = None,
    packet_ids: Iterable[int] | None = None,
) -> set[tuple[Link, Link]]:
    """All link-to-link dependencies ``policy`` can generate on ``mesh``.

    ``route_class`` restricts enumeration to packets of one class;
    ``packet_ids`` defaults to one id per class (routes are class-pure
    by definition of :meth:`RoutingPolicy.route_class`) plus a few extra
    draws so packet-seeded tie-breaks (odd-even) are sampled.
    """
    if packet_ids is None:
        packet_ids = range(max(policy.route_classes, 1) * 2)
    deps: set[tuple[Link, Link]] = set()
    for pid in packet_ids:
        if route_class is not None and policy.route_class(pid) != route_class:
            continue
        for src in mesh.coords():
            for dst in mesh.coords():
                if src == dst:
                    continue
                deps.update(route_turns(policy.route(mesh, src, dst, pid)))
    return deps


def has_cycle(deps: set[tuple[Link, Link]]) -> bool:
    """Cycle detection over the channel dependency graph (iterative DFS)."""
    adj: dict[Link, list[Link]] = {}
    for a, b in deps:
        adj.setdefault(a, []).append(b)
    WHITE, GREY, BLACK = 0, 1, 2
    color: dict[Link, int] = {}
    for start in adj:
        if color.get(start, WHITE) != WHITE:
            continue
        stack: list[tuple[Link, int]] = [(start, 0)]
        color[start] = GREY
        while stack:
            node, i = stack.pop()
            nbrs = adj.get(node, ())
            if i < len(nbrs):
                stack.append((node, i + 1))
                nxt = nbrs[i]
                c = color.get(nxt, WHITE)
                if c == GREY:
                    return True
                if c == WHITE:
                    color[nxt] = GREY
                    stack.append((nxt, 0))
            else:
                color[node] = BLACK
    return False


def deadlock_free(policy, mesh: Mesh2D) -> bool:
    """True iff every route class of ``policy`` has an acyclic CDG.

    A multi-class policy (O1TURN) is reported deadlock-free when each
    class is individually acyclic — the classes must then be mapped to
    disjoint virtual networks, which
    :func:`min_vcs_for_deadlock_freedom` quantifies.
    """
    return all(
        not has_cycle(policy_dependencies(policy, mesh, route_class=c))
        for c in range(policy.route_classes)
    )


def min_vcs_for_deadlock_freedom(policy, mesh: Mesh2D) -> int:
    """VCs needed for freedom: 1 if the full turn set is acyclic, else
    the number of (individually acyclic) route classes."""
    if not has_cycle(policy_dependencies(policy, mesh)):
        return 1
    if not deadlock_free(policy, mesh):
        raise ValueError(
            f"policy {policy.name!r} has a cyclic route class on "
            f"{mesh.cols}x{mesh.rows}: not deadlock-free at any VC count"
        )
    return policy.route_classes


def turn_name(dep: tuple[Link, Link]) -> str:
    """Human-readable turn label, e.g. ``'EN@(2,3)'`` (straights: ``'EE@..'``)."""
    (a, b), (b2, c) = dep
    d1, d2 = _DIR_NAMES[_link_dir((a, b))], _DIR_NAMES[_link_dir((b2, c))]
    return f"{d1}{d2}@({b.x},{b.y})"
