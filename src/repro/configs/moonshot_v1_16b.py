"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64 experts top-6 (kimi/moonlight).
[hf:moonshotai/Moonlight-16B-A3B]"""

from repro.configs._util import reduce_for_smoke
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="transformer",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    n_experts=64,
    top_k=6,
)


def smoke_config():
    return reduce_for_smoke(CONFIG, n_experts=8, top_k=3)
