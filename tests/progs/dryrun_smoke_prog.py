"""Fast plumbing check of the dry-run path on a small (2, 4) mesh.

Uses the REAL full-size configs for the cheapest archs and smoke-size
overrides for the big ones — the goal here is exercising build_cell /
lower / compile / roofline extraction for every family and every shape
kind, not the production mesh (that is launch/dryrun.py).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses

import jax

from repro.configs import get_config, get_smoke_config
from repro.launch.dryrun import run_cell

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)


def adapt(cfg):
    """Shrink big configs so an 8-host-device compile is fast."""
    return dataclasses.replace(
        get_smoke_config(cfg.name), name=cfg.name,
        d_model=64, n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 4) or 4,
        head_dim=16, d_ff=128, vocab=512, loss_chunk=64)


CASES = [
    ("qwen1_5_0_5b", "train_4k"),
    ("qwen1_5_0_5b", "decode_32k"),
    ("phi3_5_moe", "train_4k"),
    ("gemma3_12b", "prefill_32k"),
    ("gemma3_12b", "long_500k"),
    ("rwkv6_3b", "long_500k"),
    ("recurrentgemma_2b", "decode_32k"),
    ("whisper_base", "train_4k"),
    ("whisper_base", "decode_32k"),
]

SHRINK = {"shape_overrides": True}


def shrink_shape(shape):
    import repro.launch.shapes as shp

    small = {
        "train_4k": shp.ShapeCell("train_4k", "train", 128, 8),
        "prefill_32k": shp.ShapeCell("prefill_32k", "prefill", 256, 8),
        "decode_32k": shp.ShapeCell("decode_32k", "decode", 256, 8),
        "long_500k": shp.ShapeCell("long_500k", "decode", 1024, 1),
    }
    shp.SHAPES.update(small)


if __name__ == "__main__":
    shrink_shape(None)
    failures = []
    for arch, shape in CASES:
        cfg = adapt(get_config(arch))
        rec = run_cell(arch, shape, cfg_override=cfg, mesh=mesh, mesh_name="2x4")
        if rec["status"] != "ok":
            failures.append((arch, shape, rec.get("error", rec.get("reason"))))
        else:
            assert rec["hlo_flops"] > 0, (arch, shape, "zero flops")
            assert rec["bytes_per_device"] > 0, (arch, shape, "zero memory")
    if failures:
        for f in failures:
            print("FAIL:", f)
        raise SystemExit(1)
    print("ALL OK")
