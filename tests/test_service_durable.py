"""Durable simulation service: crash-safe result store, TCP transport
with shared-token auth, client retry/resume, bounded admission, drain.

The load-bearing invariants:

* the on-disk result store survives anything short of disk loss — torn
  final lines are dropped and compacted away, duplicate keys resolve
  last-write-wins, and a store written by a different code version is
  refused with a message naming the differing component;
* restart survival is *exact*: SIGKILL the server mid-stream, restart
  it on the same store, and a resuming client completes with rows
  bit-identical to the direct API and **zero duplicate compute**
  (points completed before the kill come back as store hits — the
  accounting ``hits + joins + computed == total`` holds across the
  restart);
* TCP connections are refused before any job parsing unless the first
  line is the shared-token handshake;
* nothing hangs: waits raise :class:`ServiceTimeout`, overload raises
  :class:`ServiceOverloaded` with a retry-after hint, and a graceful
  drain finishes in-flight jobs before the server exits.
"""

from __future__ import annotations

import json
import signal
import socket
import threading
import time

import pytest

from repro.core.noc.resilience import SuperviseConfig
from repro.core.noc.service import (
    ResultStore,
    SchedulerOverloaded,
    ServerProcess,
    ServiceClient,
    ServiceError,
    ServiceOverloaded,
    ServiceTimeout,
    SimulationServer,
    StoreMismatch,
)
from repro.core.noc.service.scheduler import Scheduler
from repro.core.noc.traffic.sweep import saturation_sweep
from repro.core.topology import Mesh2D

GRID = dict(mesh=(4, 4), pattern="transpose",
            rates=[0.02, 0.04, 0.06, 0.08, 0.1, 0.12],
            packets_per_node=2, seed=7)


def _direct():
    return saturation_sweep(Mesh2D(4, 4), "transpose", GRID["rates"],
                            packets_per_node=2, seed=7)


# ---------------------------------------------------------------------------
# Result store: torn writes, duplicates, version identity.
# ---------------------------------------------------------------------------


def test_store_roundtrip(tmp_path):
    path = str(tmp_path / "rs.jsonl")
    with ResultStore(path) as st:
        st.append("a", {"v": 1.5})
        st.append("b", {"v": [1, 2]})
        assert "a" in st and len(st) == 2
    st2 = ResultStore(path)
    assert st2.rows() == {"a": {"v": 1.5}, "b": {"v": [1, 2]}}
    assert st2.rows_loaded == 2
    assert st2.torn_dropped == 0 and st2.duplicates_compacted == 0
    st2.close()


def test_store_torn_final_line_dropped_and_compacted(tmp_path):
    path = str(tmp_path / "rs.jsonl")
    with ResultStore(path) as st:
        st.append("a", {"v": 1})
        st.append("b", {"v": 2})
    with open(path, "a") as f:          # crash mid-append: a torn line
        f.write('{"key": "c", "ro')
    st2 = ResultStore(path)
    assert st2.rows() == {"a": {"v": 1}, "b": {"v": 2}}
    assert st2.torn_dropped == 1
    st2.close()
    st3 = ResultStore(path)             # compaction removed the damage
    assert st3.torn_dropped == 0 and len(st3) == 2
    st3.close()


def test_store_duplicate_keys_last_write_wins(tmp_path):
    path = str(tmp_path / "rs.jsonl")
    with ResultStore(path) as st:
        st.append("a", {"v": 1})
        st.append("a", {"v": 2})        # two lines on disk, one key
    st2 = ResultStore(path)
    assert st2.rows() == {"a": {"v": 2}}
    assert st2.duplicates_compacted == 1
    st2.close()
    st3 = ResultStore(path)
    assert st3.duplicates_compacted == 0    # compacted away
    st3.close()


def _rewrite_header(path: str, mutate) -> None:
    with open(path) as f:
        lines = f.read().split("\n")
    header = json.loads(lines[0])
    mutate(header)
    lines[0] = json.dumps(header)
    with open(path, "w") as f:
        f.write("\n".join(lines))


def test_store_version_mismatch_names_component(tmp_path):
    path = str(tmp_path / "rs.jsonl")
    with ResultStore(path) as st:
        st.append("a", {"v": 1})
    _rewrite_header(path, lambda h: h["parts"].update(row_fields="0" * 64))
    with pytest.raises(StoreMismatch, match="SweepPoint row fields"):
        ResultStore(path)
    _rewrite_header(
        path, lambda h: h["parts"].update(params_fields="1" * 64))
    with pytest.raises(StoreMismatch,
                       match="NoCParams fields.*SweepPoint row fields"):
        ResultStore(path)


def test_store_predating_component_digests_refused(tmp_path):
    path = str(tmp_path / "rs.jsonl")
    ResultStore(path).close()
    _rewrite_header(path, lambda h: h.pop("parts"))
    with pytest.raises(StoreMismatch, match="predates per-component"):
        ResultStore(path)


# ---------------------------------------------------------------------------
# Warm restart: a fresh server on an existing store serves from disk.
# ---------------------------------------------------------------------------


def test_fresh_server_on_existing_store_serves_store_hits(tmp_path):
    path = str(tmp_path / "rs.jsonl")
    direct = _direct()
    with SimulationServer(workers=0, chunk_tokens=3, store=path) as srv:
        with ServiceClient(srv.path) as cli:
            cold = cli.submit_sweep(**GRID).sweep_points()
            cold_stats = cli.stats()
    assert cold == direct
    assert cold_stats["points"]["computed"] == 6
    assert cold_stats["store"]["appends"] == 6

    with SimulationServer(workers=0, chunk_tokens=3, store=path) as srv:
        with ServiceClient(srv.path) as cli:
            warm = cli.submit_sweep(**GRID).sweep_points()
            st = cli.stats()["points"]
    assert warm == direct                       # bit-identical from disk
    assert st["store_hits"] == 6
    assert st["computed"] == 0
    assert (st["memo_hits"] + st["inflight_joins"]
            + st["computed"]) == st["total"] == 6


# ---------------------------------------------------------------------------
# The centerpiece: SIGKILL mid-stream, restart, resume — zero duplicate
# compute.
# ---------------------------------------------------------------------------


def test_kill9_restart_resume_bit_identical_zero_duplicate(tmp_path):
    direct = _direct()
    sock = str(tmp_path / "svc.sock")
    store = str(tmp_path / "rs.jsonl")
    # workers=0 + chunk_tokens=1: points complete one at a time, so
    # chaos_kill_server_after=2 dies with exactly 2 rows durable.
    srv1 = ServerProcess(sock, store=store, workers=0, chunk_tokens=1,
                         chaos_kill_server_after=2)
    result: dict = {}
    errors: list = []

    def run_client():
        try:
            with ServiceClient(sock, resume=True, max_retries=60,
                               backoff_base_s=0.05,
                               backoff_cap_s=0.25) as cli:
                h = cli.submit_sweep(**GRID)
                result["pts"] = h.sweep_points()
                result["stats"] = cli.stats()
        except Exception as exc:  # noqa: BLE001
            errors.append(exc)

    t = threading.Thread(target=run_client)
    t.start()
    code = srv1.wait(timeout=180)               # the chaos SIGKILL fires
    assert code == -signal.SIGKILL
    assert t.is_alive()                         # client is retrying, not dead

    with ServerProcess(sock, store=store, workers=0, chunk_tokens=1):
        t.join(timeout=180)
        assert not t.is_alive()
    assert not errors, errors
    assert result["pts"] == direct              # bit-identical across restart

    st = result["stats"]["points"]
    assert st["total"] == 6
    assert st["store_hits"] == 2                # pre-kill rows, from disk
    assert st["computed"] == 4                  # zero duplicate compute
    assert (st["memo_hits"] + st["inflight_joins"]
            + st["computed"]) == st["total"]
    with ResultStore(store) as final:           # every point is now durable
        assert len(final) == 6


# ---------------------------------------------------------------------------
# TCP transport and auth.
# ---------------------------------------------------------------------------


def test_unauthenticated_tcp_refused_before_job_parsing():
    with SimulationServer(workers=0, tcp=("127.0.0.1", 0),
                          token="s3cret") as srv:
        host, port = srv.tcp_address
        raw = socket.create_connection((host, port), timeout=10)
        try:
            raw.sendall(b'{"op": "submit", "req": "r1", "job": {}}\n')
            reply = json.loads(raw.recv(65536).split(b"\n", 1)[0])
            assert reply["event"] == "auth_error"
            assert raw.recv(65536) == b""       # connection closed on us
        finally:
            raw.close()
        with pytest.raises(ServiceError, match="auth"):
            ServiceClient((host, port), token="wr0ng")
        with ServiceClient(srv.path) as cli:    # nothing was ever parsed
            assert cli.stats()["jobs"]["submitted"] == 0


def test_tcp_requires_token_on_both_ends():
    with pytest.raises(ValueError, match="token"):
        SimulationServer(workers=0, tcp=("127.0.0.1", 0))
    with pytest.raises(ValueError, match="token"):
        ServiceClient(("127.0.0.1", 1))


# ---------------------------------------------------------------------------
# Timeouts, overload, drain.
# ---------------------------------------------------------------------------


def test_wait_timeout_raises_service_timeout_not_hang():
    with SimulationServer(workers=0, chunk_tokens=1) as srv:
        with ServiceClient(srv.path) as cli:
            h = cli.submit_sweep(**GRID)
            with pytest.raises(ServiceTimeout) as ei:
                h.wait(timeout=0.01)
            assert isinstance(ei.value, TimeoutError)   # old handlers work
            assert h.wait(timeout=180) == "done"


def test_admission_bound_rejects_then_accepts_warm(tmp_path):
    direct = _direct()
    with SimulationServer(workers=0, chunk_tokens=1,
                          max_queue_points=4) as srv:
        with ServiceClient(srv.path) as cli:
            h = cli.submit_sweep(**GRID)        # 6 fresh points > bound 4
            with pytest.raises(ServiceOverloaded) as ei:
                h.collect()
            assert ei.value.retry_after_s > 0
            assert "admission queue full" in str(ei.value)

            small = dict(GRID, rates=GRID["rates"][:2])
            assert len(cli.submit_sweep(**small).collect()) == 2

            # Warm resubmission: 2 of 6 points are memoized now, so only
            # 4 are fresh — within the bound, accepted, bit-identical.
            assert cli.submit_sweep(**GRID).sweep_points() == direct


def test_scheduler_overload_message_has_retry_hint():
    from repro.core.noc.service import SweepJob

    with Scheduler(workers=0, max_queue_points=1) as sched:
        doc = SweepJob(**GRID).to_doc()
        with pytest.raises(SchedulerOverloaded) as ei:
            sched.submit("c1", doc, lambda e: None)
        assert "retry after" in str(ei.value)
        assert ei.value.retry_after_s > 0


def test_drain_finishes_inflight_rejects_new_flushes_store(tmp_path):
    path = str(tmp_path / "rs.jsonl")
    with SimulationServer(workers=0, chunk_tokens=1, store=path) as srv:
        with ServiceClient(srv.path) as cli:
            h = cli.submit_sweep(**GRID)
            assert h.rows_total == 6            # accepted before we drain
            stats = srv.drain(timeout=180)
            assert stats["draining"] is True
            assert stats["jobs"]["done"] == 1   # in-flight job completed
            assert h.wait(timeout=30) == "done"
            h2 = cli.submit_sweep(**GRID)       # existing conn, new job
            with pytest.raises(ServiceOverloaded, match="draining"):
                h2.collect()
    with ResultStore(path) as st:
        assert len(st) == 6


def test_sigterm_drains_flushes_and_exits_zero(tmp_path):
    sock = str(tmp_path / "svc.sock")
    store = str(tmp_path / "rs.jsonl")
    with ServerProcess(sock, store=store, workers=0, chunk_tokens=2) as srv:
        with ServiceClient(sock) as cli:
            assert cli.submit_sweep(**GRID).wait(timeout=180) == "done"
        srv.terminate()
        assert srv.wait(timeout=30) == 0
    with ResultStore(store) as st:
        assert len(st) == 6


# ---------------------------------------------------------------------------
# Client resilience details.
# ---------------------------------------------------------------------------


def test_resume_client_event_seq_is_monotonic_and_complete():
    with SimulationServer(workers=0, chunk_tokens=1) as srv:
        with ServiceClient(srv.path, resume=True) as cli:
            h = cli.submit_sweep(**GRID)
            assert h.sweep_points() == _direct()
            # accepted(0) + one rows event per point (1..6) + done(7).
            assert h.last_seq == 7


def test_resume_client_can_start_before_server(tmp_path):
    sock = str(tmp_path / "late.sock")
    holder: dict = {}

    def start_later():
        time.sleep(0.4)
        holder["srv"] = SimulationServer(path=sock, workers=0)

    t = threading.Thread(target=start_later)
    t.start()
    try:
        with ServiceClient(sock, resume=True, max_retries=40,
                           backoff_base_s=0.05, backoff_cap_s=0.25) as cli:
            small = dict(GRID, rates=GRID["rates"][:1])
            assert len(cli.submit_sweep(**small).collect()) == 1
    finally:
        t.join(timeout=10)
        holder["srv"].close()


def test_nonresuming_client_fails_fast_on_missing_server(tmp_path):
    with pytest.raises(OSError):
        ServiceClient(str(tmp_path / "nobody-home.sock"))


# ---------------------------------------------------------------------------
# Supervision: reap escalation deadlines are configurable end to end.
# ---------------------------------------------------------------------------


def _sigterm_immune_worker(conn, heartbeat, cache_capacity):
    """A worker that ignores SIGTERM and never reads its pipe — only the
    reap escalation's SIGKILL can take it down."""
    signal.signal(signal.SIGTERM, signal.SIG_IGN)
    while True:
        time.sleep(60)


def test_reap_escalation_kills_sigterm_immune_worker(monkeypatch):
    from repro.core.noc.service import scheduler as sched_mod

    monkeypatch.setattr(sched_mod, "_worker_main", _sigterm_immune_worker)
    cfg = SuperviseConfig(join_timeout_s=0.2, term_timeout_s=0.2)
    t0 = time.perf_counter()
    srv = SimulationServer(workers=1, supervise=cfg)
    procs = [w.proc for w in srv.scheduler._workers]
    assert procs and all(p.is_alive() for p in procs)
    srv.close()
    elapsed = time.perf_counter() - t0
    assert all(not p.is_alive() for p in procs)
    # join(0.2) + ignored SIGTERM + join(0.2) + SIGKILL: the short
    # deadlines keep teardown fast; the 5s default would too, but this
    # asserts the knobs actually reach reap().
    assert elapsed < 10.0
