"""Simulation-as-a-service: a persistent NoC evaluation server.

Design-space exploration hammers the same simulations from many
callers — parameter sweeps share (mesh, params, population) points,
CI jobs re-run yesterday's grids, notebook users iterate on one corner.
This package turns the one-shot ``saturation_sweep`` / ``run_program``
APIs into a long-lived local service that exploits that redundancy:

``jobs``
    Declarative job documents (sweep / policy-compare / run-program)
    with canonical fingerprints, and the single
    :func:`~.jobs.execute_workload` path every result is computed
    through.
``cache``
    The compile-artifact LRU and the completed-point result memo, with
    exact hit/miss/eviction accounting.
``scheduler``
    Slot-based dispatch over persistent supervised fork workers:
    per-client fairness, in-flight point coalescing, worker
    kill/wedge recovery with chunk retry, degradation to in-process.
``server`` / ``client``
    A local-socket JSONL protocol with concurrent clients, streamed
    result rows and cancellation.

The contract throughout: every row a client receives is bit-identical
to calling the direct API yourself — memoized or freshly computed,
fanned out or serial (the service runs the exact compile-once
``measure``/``run_program`` code paths; tests assert equality field by
field).
"""

from repro.core.noc.service.cache import (  # noqa: F401
    CacheStats,
    CompileCache,
    ResultMemo,
)
from repro.core.noc.service.client import (  # noqa: F401
    JobHandle,
    ServiceClient,
    ServiceError,
)
from repro.core.noc.service.jobs import (  # noqa: F401
    PolicyCompareJob,
    RunProgramJob,
    SweepJob,
    execute_workload,
    job_from_doc,
)
from repro.core.noc.service.scheduler import Scheduler  # noqa: F401
from repro.core.noc.service.server import SimulationServer  # noqa: F401
