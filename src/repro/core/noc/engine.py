"""Execution engines for the flit-level NoC simulator.

The original ``NoCSim.run()`` advanced global time one cycle per Python
loop iteration.  That is fine for a 4x4 micro-benchmark but hopeless for
saturation sweeps: a DMA round-trip alone is ~50 idle cycles per stream,
and trace replays of barrier-separated phases spend most of their cycles
with *no* beat eligible to move anywhere.

Two accelerated engines keep the per-cycle arbitration semantics
**bit-identical** (same round-robin start offset, same busy-link set,
same within-cycle request ordering) to the legacy loop.  All three
arbitrate one beat per (physical link, virtual channel) per cycle —
streams carry a ``vc`` assigned from their traffic class (or packet id)
by ``NoCParams.vc_of``, so collective and unicast classes stop blocking
each other head-of-line once ``num_vcs > 1``, while ``num_vcs=1``
reproduces the historical whole-link arbitration exactly:

``run_event_driven``
    Fast-forwards over idle gaps: whenever a cycle ends with no beat
    having crossed any edge, time jumps to the minimum per-stream
    readiness threshold.  Still O(streams) per active cycle — every
    pending stream is scanned, and ``requests()`` re-walks a stream's
    whole edge set.

``run_heap``
    The hot path for large meshes.  Pending streams live in a global
    min-heap keyed on their *exact* next-ready cycle (the same integer
    thresholds ``_StreamState._ready_after`` solves), so a cycle touches
    only the streams that can actually move.  Invariants:

    * **Lazy invalidation** — heap entries are never removed in place; an
      entry is valid only while it matches the stream's currently
      scheduled cycle (``sched``), and stale entries are dropped on pop.
      Within a stream, the per-unit heap uses the same discipline against
      the cached ``_unit_ready`` cycles.
    * **Round-robin tie-breaking** — the legacy loop rotates the pending
      list by ``rr % len(pending)`` each cycle and consumes one counter
      slot per cycle (idle or not).  The heap engine reproduces this
      exactly: a Fenwick tree maintains each stream's *live position*
      (its index in the pending list the legacy loop would have built),
      ready streams are processed in rotated live-position order, and the
      arbitration counter is advanced by the final cycle count on exit —
      so same-cycle entries fire in the identical order and results are
      bit-identical, arbitration counter included.
    * **Incremental readiness** — streams expose ``ready_units`` /
      ``advance_unit`` frontier cursors; an advance dirties only the unit
      itself and its downstream consumer units, never the full edge walk.

``noc.shard`` adds a fourth bit-identical engine on top of these
invariants: ``engine='shard'`` partitions the mesh into rectangular
regions (links partition cleanly because every unit's edges share a
source tile) and runs each region's per-(link, VC) arbitration
independently inside conservatively bounded epochs, reconciling
boundary arrivals, completions and gate releases at epoch edges —
serially or on fork-worker processes.  See the ``shard`` module
docstring for the exactness argument.

Cross-stream *gates* (``_StreamState.gates``) are the engines' only
inter-stream dependency mechanism: a gated stream's inject clock starts
the cycle after its last gate stream drains.  They were introduced for
sliding-window trace replay and are now the lowering target of the
program IR's per-op dependency edges (``noc.program.run_program
(mode='op')``), including the link-free timed streams that ComputeOp /
BarrierOp nodes lower to — all three engines handle gate release
identically (``gate_dependents`` + ``gate_released``).

If no pending stream has a finite readiness threshold the network can
never progress again; all engines raise immediately with a per-stream
stall report (which streams are stuck, their final-edge frontier beats,
and the blocking edges) instead of spinning to ``max_cycles``.

Pause / resume contract (checkpoint substrate)
----------------------------------------------

Every engine accepts a half-open simulation window ``[start, stop_at)``
(``NoCSim.run(stop_at=..., start_cycle=...)``).  A run paused at cycle
``C`` and resumed with ``start_cycle=C`` is **bit-identical** to an
uninterrupted run — same arrivals, done cycles and ``_rr`` — because:

* One arbitration slot is consumed per cycle in the window, idle gaps
  included: a paused engine leaves ``_rr = rr_base + (C - start)``, so
  the rotation key at absolute cycle ``t`` is always
  ``(rr_base_0 + t) % n_live`` regardless of where the run was split.
* Readiness thresholds recomputed from arrivals on resume can predate
  ``C`` (arbitration losers whose beat was ready before the pause);
  engines clamp the initial schedule to ``max(threshold, start)`` —
  those cycles were already simulated, the stream just kept losing.
* Gate origins (``_t0``), completion counters and heap caches are all
  derived from arrivals/done cycles, never from wall state, so
  ``_heap_init`` / ``_Region.init_run`` rebuild them exactly.

``resilience/checkpoint.py`` serializes exactly the state this contract
depends on — per-stream arrival lists, done cycles, gate wiring, exact
Fraction inject/rate schedules, provenance, and sim-level ``_rr`` /
``_pkt_seq`` / fault counters / CDG dependencies — as a versioned,
sha256-fingerprinted JSON document (format ``repro-noc-checkpoint``,
see that module).  ``restore()`` rebuilds streams through the plain
``_StreamState`` constructor, so a resumed run re-derives every cache
from the serialized ground truth.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import TYPE_CHECKING, Optional, Sequence

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from repro.core.noc.netsim import NoCSim, _StreamState


@dataclasses.dataclass
class EngineProfile:
    """Lightweight engine counters from ``NoCSim.run(profile=True)``.

    The data needed to tune the heap/shard hot paths — how much scheduler
    churn a scenario causes (heap pushes/pops, lazily dropped stale
    entries) and, for the shard engine, how the epoch protocol behaved
    (epoch count, boundary arrivals reconciled across regions) — which is
    what region-size tuning reads.  Counters that do not apply to the
    engine that ran stay 0.
    """

    engine: str = "heap"
    makespan: int = 0
    advances: int = 0              # beats advanced (units fired)
    heap_pushes: int = 0           # global scheduler heap pushes
    heap_pops: int = 0             # global scheduler heap pops
    lazy_invalidations: int = 0    # stale entries dropped on pop
    epochs: int = 0                # shard: bounded epochs executed
    boundary_reconciliations: int = 0  # shard: boundary arrivals shipped
    regions: int = 0               # shard: region count
    workers: int = 0               # shard: worker processes used (0=serial)
    # Fault-injection counters (0 on a pristine mesh): accumulated at
    # stream construction time by NoCSim and copied here so degraded runs
    # are observable in run(profile=True) output and bench rows.
    retries_paid: int = 0          # beat crossings that paid a flaky retry
    detoured_routes: int = 0       # unicasts re-routed around dead elements
    regrafted_trees: int = 0       # fork/join trees rebuilt around faults
    # Resilience counters: shard worker supervision (recoveries during a
    # fork-backend run) and mid-run fault arrival (timeline events applied
    # between run segments, streams re-lowered or dropped by them).
    worker_retries: int = 0        # shard: ops retried after a worker failure
    worker_respawns: int = 0       # shard: workers respawned (log replay)
    worker_degradations: int = 0   # shard: fork -> in-process degradations
    fault_events: int = 0          # mid-run FaultTimeline events applied
    relowered_streams: int = 0     # live streams re-lowered at a fault event
    dropped_streams: int = 0       # live streams dropped (dead endpoint)

    def counters(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("engine")
        d.pop("makespan")
        return d

    def absorb(self, seg: "EngineProfile") -> None:
        """Fold one run segment's profile into this accumulator (used by
        checkpointed / timeline runs, which split one logical run into
        several ``run()`` calls).  Additive by default — every counter
        not named in an exclusion set sums across segments, so a newly
        added field folds correctly without touching this method.
        ``ABSORB_LATEST`` fields take the latest segment's value
        (makespan, plus the sim-cumulative fault/resilience counters that
        ``NoCSim._fault_counts`` already accumulates across calls);
        ``ABSORB_MAX`` fields keep the peak; ``ABSORB_SKIP`` fields are
        handled explicitly below."""
        for f in dataclasses.fields(self):
            k = f.name
            if k in ABSORB_SKIP:
                continue
            if k in ABSORB_LATEST:
                setattr(self, k, getattr(seg, k))
            elif k in ABSORB_MAX:
                setattr(self, k, max(getattr(self, k), getattr(seg, k)))
            else:
                setattr(self, k, getattr(self, k) + getattr(seg, k))
        self.engine = seg.engine


# absorb() exclusion sets: fields that do NOT sum across run segments.
# Latest-wins: makespan plus the counters NoCSim._fault_counts already
# accumulates sim-side across run() calls (summing would double-count).
ABSORB_LATEST = frozenset({
    "makespan", "retries_paid", "detoured_routes", "regrafted_trees",
    "fault_events", "relowered_streams", "dropped_streams",
})
# Peak-wins: configuration extents, not work counters.
ABSORB_MAX = frozenset({"regions", "workers"})
# Non-numeric / handled explicitly in absorb().
ABSORB_SKIP = frozenset({"engine"})


def gate_dependents(streams: Sequence["_StreamState"]) -> dict[int, list["_StreamState"]]:
    """Map ``id(gate stream) -> [streams gated on it]`` (window replay)."""
    deps: dict[int, list] = {}
    for s in streams:
        for g in s.gates:
            deps.setdefault(id(g), []).append(s)
    return deps


def stuck_error(sim: "NoCSim", kind: str, t: int, stuck: Sequence["_StreamState"]) -> RuntimeError:
    """Build the deadlock/timeout error: name the stuck streams, their
    final-edge frontier beats and the blocking edges, not just the cycle.

    With faults active the report additionally names the faulted
    links/routers adjacent to the stuck frontier and says so in the
    headline — distinguishing "deadlocked" from "destination unreachable
    under current faults" at a glance."""
    idx = {id(s): i for i, s in enumerate(sim.streams)}
    faults = getattr(sim, "faults", None)
    lines = []
    for s in stuck[:4]:
        lines.append(f"  stream#{idx.get(id(s), '?')}: {s.stall_report()}")
    more = len(stuck) - 4
    if more > 0:
        lines.append(f"  ... and {more} more stuck stream(s)")
    if faults is not None:
        frontier = {c for s in stuck for e in s.edges() for c in e}
        implicated = faults.implicated(frontier)
        lines.append(f"  faults active ({faults.describe()})")
        if implicated:
            lines.append(
                "  implicated at the stuck frontier: "
                + "; ".join(implicated[:6]))
    else:
        lines.append("  no faults active")
    detail = "\n".join(lines)
    return RuntimeError(
        f"netsim {kind} at cycle {t}"
        f"{' under active faults' if faults is not None else ''}: "
        f"{len(stuck)} of {len(sim.streams)} stream(s) cannot advance\n{detail}"
    )


def run_event_driven(sim: "NoCSim", max_cycles: int,
                     stop_at: Optional[int] = None, start: int = 0) -> int:
    """Advance ``sim`` until all streams complete; returns last done cycle.

    Produces exactly the same per-stream arrival times and completion
    cycles as the legacy one-iteration-per-cycle loop.  With ``stop_at``
    the engine simulates cycles in ``[start, stop_at)`` only and returns
    ``stop_at`` when streams remain — the pause/resume contract in the
    module docstring.
    """
    dependents = gate_dependents(sim.streams)
    tel = getattr(sim, "telemetry", None)
    t = start
    limit = max_cycles if stop_at is None else min(max_cycles, stop_at)
    while t < limit:
        pending = [s for s in sim.streams if s.done_cycle is None]
        if not pending:
            break
        busy: set = set()  # (physical link, VC) pairs claimed this cycle
        progressed = False
        start = sim._rr_next() % len(pending)
        for s in pending[start:] + pending[:start]:
            # Skip streams whose cached hint proves they cannot move yet;
            # requests() on them would walk every edge just to return [].
            hint = s.ready_hint
            if hint is not None and t < hint:
                continue
            reqs = s.requests(t)
            if not reqs:
                c = s.next_ready_cycle()
                s.ready_hint = math.inf if c is None else max(c, t + 1)
                continue
            vc = s.vc
            for group in reqs:
                links = [e for e in group if e[0] != e[1]]
                if any((e, vc) in busy for e in links):
                    continue
                busy.update((e, vc) for e in links)
                s.advance(group, t)  # resets the stream's ready_hint
                progressed = True
                if tel is not None:
                    tel.count_group(s, group)
            if s.done_cycle is not None:
                for dep in dependents.get(id(s), ()):
                    dep.gate_released()  # resets the dependent's ready_hint
        if progressed:
            t += 1
            continue
        # Idle cycle: jump to the earliest cycle any stream could advance.
        # Every pending stream now carries a hint (set above or still valid).
        nxt = math.inf
        for s in pending:
            hint = s.ready_hint
            if hint is None:  # ready at t but lost every link arbitration
                nxt = t + 1
                break
            nxt = min(nxt, hint)
        if nxt == math.inf:
            raise stuck_error(sim, "deadlock", t, pending)
        nxt = min(max(int(nxt), t + 1), limit)  # never skip past the window
        sim._rr_skip(nxt - t - 1)  # idle cycles still consume arbitration slots
        t = nxt
    unfinished = [s for s in sim.streams if s.done_cycle is None]
    if unfinished:
        if stop_at is not None and stop_at <= max_cycles:
            return stop_at  # paused at the window boundary, not stuck
        raise stuck_error(sim, "deadlock/timeout", t, unfinished)
    if not sim.streams:
        return 0
    return max(s.done_cycle for s in sim.streams)


class _Fenwick:
    """Binary indexed tree over original stream indices; 1 = still pending.

    ``prefix(i)`` = number of live streams with index < i = the stream's
    position in the pending list the legacy engine would have built, which
    is what the round-robin rotation is defined over.
    """

    __slots__ = ("n", "tree")

    def __init__(self, n: int):
        self.n = n
        self.tree = [0] * (n + 1)

    def add(self, i: int, delta: int) -> None:
        i += 1
        while i <= self.n:
            self.tree[i] += delta
            i += i & -i

    def prefix(self, i: int) -> int:
        s = 0
        while i > 0:
            s += self.tree[i]
            i -= i & -i
        return s


def run_heap(sim: "NoCSim", max_cycles: int,
             prof: Optional[EngineProfile] = None,
             stop_at: Optional[int] = None, start: int = 0) -> int:
    """Heap-scheduled engine: bit-identical to the per-cycle loop, but a
    cycle only ever touches the streams whose exact next-ready threshold
    has been reached (plus carried arbitration losers).  ``[start,
    stop_at)`` windows the simulated cycles (pause/resume contract, see
    module docstring): the rotation key at absolute cycle ``t`` is
    ``(rr_base + t - start) % n_live`` and a paused run leaves
    ``_rr = rr_base + (stop_at - start)``."""
    streams = sim.streams
    n = len(streams)
    live = [s.done_cycle is None for s in streams]
    n_live = sum(live)
    if n_live == 0:
        if not streams:
            return 0
        return max(s.done_cycle for s in streams)

    dependents = gate_dependents(streams)
    dep_idx: dict[int, list[int]] = {}
    if dependents:
        pos_of = {id(s): i for i, s in enumerate(streams)}
        dep_idx = {
            pos_of[gid]: [pos_of[id(d)] for d in ds]
            for gid, ds in dependents.items()
            if gid in pos_of
        }

    fen = _Fenwick(n)
    gheap: list[tuple[int, int]] = []   # (next-ready cycle, stream index)
    sched: list = [None] * n            # lazy-invalidation: entry valid iff
                                        # its cycle == sched[stream index]
    # Busy-link arbitration interns each (physical link, VC) pair as a
    # small int so the inner busy-set tests never hash Coord tuples.
    # Streams in different VCs intern disjoint ids for the same link and
    # therefore never collide; with num_vcs=1 the partition is identical
    # to the historical whole-link interning.
    link_id: dict = {}
    linkids: list = [None] * n          # per stream: per unit, tuple of ids
    # Telemetry stays out of the hot loop: per-unit fire counts go into
    # flat arrays and fold into the collector once at run exit.
    tel = getattr(sim, "telemetry", None)
    tfires: list = [None] * n
    for i, s in enumerate(streams):
        if not live[i]:
            continue
        fen.add(i, 1)
        s._heap_init()
        vc = s.vc
        linkids[i] = [
            tuple(
                link_id.setdefault((e, vc), len(link_id)) for e in links
            )
            for links in s._unit_links
        ]
        if tel is not None:
            tfires[i] = [0] * len(s._units)
        c = s.next_ready()
        if c is not None:
            if c < start:
                c = start  # ready before the resume point: cycles < start
                           # were already simulated (arbitration losses)
            sched[i] = c
            gheap.append((c, i))
    heapq.heapify(gheap)

    rr_base = sim._rr
    t = start - 1   # last processed cycle
    carry: list[int] = []  # streams still ready after losing arbitration at t
    n_adv = n_pop = n_stale = 0
    n_push = len(gheap)  # initial population counts as pushes
    paused = False
    while n_live:
        if carry:
            t_next = t + 1
        else:
            t_next = None
            while gheap:
                c, i = gheap[0]
                if not live[i] or sched[i] != c:
                    heapq.heappop(gheap)  # stale (lazy invalidation)
                    n_stale += 1
                    continue
                t_next = c
                break
            if t_next is None:
                raise stuck_error(
                    sim, "deadlock", t + 1,
                    [s for i, s in enumerate(streams) if live[i]],
                )
        if stop_at is not None and t_next >= stop_at and stop_at <= max_cycles:
            paused = True
            break
        if t_next >= max_cycles:
            raise stuck_error(
                sim, "deadlock/timeout", max_cycles,
                [s for i, s in enumerate(streams) if live[i]],
            )
        t = t_next

        ready = set(carry)
        carry = []
        while gheap and gheap[0][0] <= t:
            c, i = heapq.heappop(gheap)
            n_pop += 1
            if live[i] and sched[i] == c:
                ready.add(i)
            else:
                n_stale += 1
        # Rotated live-position order == the legacy pending-list rotation.
        rot = (rr_base + t - start) % n_live
        ordered = sorted(
            ready, key=lambda i: (fen.prefix(i) - rot) % n_live
        )
        busy: set = set()
        finished: list[int] = []
        for i in ordered:
            s = streams[i]
            lids = linkids[i]
            tf = tfires[i]
            for ui in list(s.ready_units(t)):
                links = lids[ui]
                if any(e in busy for e in links):
                    continue
                busy.update(links)
                s.advance_unit(ui, t)
                n_adv += 1
                if tf is not None:
                    tf[ui] += 1
            if s.done_cycle is not None:
                finished.append(i)
                continue
            c = s.next_ready()
            if c is None:
                sched[i] = None       # blocked until a gate stream drains
            elif c <= t + 1:
                sched[i] = t + 1      # still ready (or ready again) next cycle
                carry.append(i)
            else:
                sched[i] = c
                heapq.heappush(gheap, (c, i))
                n_push += 1
        for i in finished:
            live[i] = False
            sched[i] = None
            fen.add(i, -1)
            n_live -= 1
            for d in dep_idx.get(i, ()):
                if not live[d]:
                    continue
                sd = streams[d]
                if any(g.done_cycle is None for g in sd.gates):
                    continue
                sd.gate_released()
                c = sd.next_ready()
                if c is not None and (sched[d] is None or c < sched[d]):
                    sched[d] = c
                    heapq.heappush(gheap, (c, d))
                    n_push += 1
    # One arbitration slot per cycle examined, exactly like the legacy
    # loop (idle gaps included): cycles start..t inclusive — or the whole
    # window [start, stop_at) on pause, trailing idle cycles included, so
    # a resume continues the counter exactly where an uninterrupted run
    # would stand at stop_at.
    if paused:
        sim._rr = rr_base + (stop_at - start)
    else:
        sim._rr = rr_base + (t - start) + 1
    if tel is not None:
        for i, tf in enumerate(tfires):
            if tf is not None:
                tel.add_stream_fires(streams[i], tf)
    if prof is not None:
        prof.advances += n_adv
        prof.heap_pushes += n_push
        prof.heap_pops += n_pop
        prof.lazy_invalidations += n_stale
    if paused:
        return stop_at
    return max(s.done_cycle for s in streams)
