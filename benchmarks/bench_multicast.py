"""Figures 5a/5b/5c: multicast runtimes — SW schedules vs in-network HW.

Also cross-validates the analytical models against the flit-level
simulator, mirroring the paper's model-vs-RTL-measurement validation.
"""

from __future__ import annotations

from repro.core.noc import model as m
from repro.core.noc.netsim import NoCSim
from repro.core.noc.params import PAPER_MICRO
from repro.core.topology import Coord, Mesh2D, Submesh

KIB = 1024
SIZES = [1 * KIB, 2 * KIB, 4 * KIB, 8 * KIB, 16 * KIB, 32 * KIB]


def rows():
    p = PAPER_MICRO
    out = []
    # Fig 5a: 1-D multicast, c=4
    for size in SIZES:
        n = p.beats(size)
        naive = m.multicast_naive(p, n, 4)
        seq = m.multicast_seq(p, n, 4)
        tree = m.multicast_tree(p, n, 4)
        hw = m.multicast_hw(p, n, 4)
        sw = min(seq, tree)
        out.append((f"mcast1d_{size//KIB}k_naive", naive / 1e3, naive))
        out.append((f"mcast1d_{size//KIB}k_seq", seq / 1e3, seq))
        out.append((f"mcast1d_{size//KIB}k_tree", tree / 1e3, tree))
        out.append((f"mcast1d_{size//KIB}k_hw", hw / 1e3, hw))
        out.append((f"mcast1d_{size//KIB}k_speedup", 0.0, round(sw / hw, 2)))
    # Fig 5b: T_seq -> T_hw as per-stage overhead -> 0
    n = p.beats(32 * KIB)
    for alpha_delta in (0, 8, 32, 128):
        import dataclasses

        p2 = dataclasses.replace(p, alpha0=float(alpha_delta), delta=0.0,
                                 hop_cycles=0.0)
        t = m.multicast_seq(p2, n, 4)
        out.append((f"mcast_seq_limit_ad{alpha_delta}", t / 1e3, t))
    out.append(("mcast_hw_32k(limit target)", m.multicast_hw(p, n, 4) / 1e3,
                m.multicast_hw(p, n, 4)))
    # Fig 5c: 2-D multicast at 32 KiB, rows r in {1, 2, 4}
    for r in (1, 2, 4):
        sw = m.multicast_sw_best(p, n, 4, r)
        hw = m.multicast_hw(p, n, 4, r)
        out.append((f"mcast2d_r{r}_sw", sw / 1e3, sw))
        out.append((f"mcast2d_r{r}_hw", hw / 1e3, hw))
    # model vs flit-level simulator (hw path, 4x4 mesh)
    mesh = Mesh2D(4, 4)
    for size in (1 * KIB, 32 * KIB):
        sim = NoCSim(mesh, p)
        sim.add_multicast(Coord(0, 0), Submesh(0, 0, 4, 1).multi_address(), size)
        t_sim = sim.run()
        t_model = m.multicast_hw(p, p.beats(size), 4, 1)
        out.append((f"mcast_netsim_vs_model_{size//KIB}k", t_sim / 1e3,
                    round(t_sim / t_model, 3)))
    geo = m.geomean([m.multicast_sw_best(p, p.beats(s), 4) /
                     m.multicast_hw(p, p.beats(s), 4) for s in SIZES])
    out.append(("mcast_1d_geomean_speedup(paper:2.3-3.2 range)", 0.0, round(geo, 2)))
    return out
