"""Cycle-level substrate reproducing the paper's own evaluation.

``params``    — hardware/runtime parameter sets (+ TPU-pod mapping)
``model``     — the paper's analytical runtime models, Eqs (1)-(6), (10)-(15)
``netsim``    — flit-level 2-D-mesh simulator (multicast fork / reduction join)
``engine``    — event-driven run loop: idle-gap fast-forward, bit-identical
                to the per-cycle loop; makes 16x16+ meshes tractable
``traffic``   — traffic engine subsystem:
                ``traffic.patterns``  seedable synthetic workloads (uniform,
                                      transpose, bit-complement, bit-reversal,
                                      hotspot, neighbor, all-to-all) and
                                      SUMMA/FCL collective storms
                ``traffic.trace``     TrafficEvent/Trace serialization, live
                                      TraceRecorder capture, and contended
                                      phase-by-phase replay
                ``traffic.sweep``     injection-rate vs. latency/throughput
                                      saturation curves
``energy``    — Table-1 energy model and Fig-10 scaling
``calibrate`` — validation of every numeric claim in the paper
"""

from repro.core.noc.params import NoCParams, PAPER_MICRO, PAPER_GEMM  # noqa: F401
