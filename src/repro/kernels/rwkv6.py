"""Chunked RWKV-6 WKV kernel (data-dependent-decay linear attention).

One (batch*head) slice per grid row; the chunk dimension iterates
sequentially carrying the (hd x hd) state in VMEM scratch.  Within a chunk
everything is MXU matmuls: the intra-chunk term is a masked (c x c)
attention-like product, the inter-chunk term a (c, hd) x (hd, hd) matmul —
the same formulation as models/rwkv6.chunked_wkv, specialized per head.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)    # (c, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)  # log-decay, <= 0
    u = u_ref[0].astype(jnp.float32)    # (1, hd) bonus

    cum = jnp.cumsum(lw, axis=0)
    p_excl = cum - lw
    A = cum[-1]

    state = s_ref[...]
    r_dec = r * jnp.exp(p_excl)
    out_inter = r_dec @ state                          # (c, hd)
    att = (r * jnp.exp(p_excl)) @ (k * jnp.exp(-cum)).T
    c = r.shape[0]
    mask = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    att = jnp.where(mask, att, 0.0)
    out_intra = att @ v
    diag = jnp.sum(r * (u * k), axis=-1, keepdims=True)
    out = out_inter + out_intra + diag * v
    k_dec = k * jnp.exp(A[None, :] - cum)
    s_ref[...] = jnp.exp(A)[:, None] * state + k_dec.T @ v
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(r, k, v, logw, u, *, chunk: int = 64, interpret: bool = True):
    """r,k,v,logw: (BH, S, hd); u: (BH, hd). Returns out (BH, S, hd)."""
    BH, S, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    return pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=(BH, S // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
