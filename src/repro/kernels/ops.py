"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container validates kernel
bodies in interpreter mode); on a TPU backend the same calls compile to
Mosaic.  ``use_kernels(cfg)`` gates kernel usage per model config.
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.gemm import gemm  # noqa: F401
from repro.kernels.reduce_nway import reduce_nway  # noqa: F401
from repro.kernels.rglru import rglru_scan  # noqa: F401
from repro.kernels.rwkv6 import wkv  # noqa: F401


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()
