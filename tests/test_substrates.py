"""Data pipeline, optimizer, compression, checkpoint, schedule tests."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLMSource, ByteFileSource
from repro.optim import (AdamWConfig, adamw_init, adamw_update, compress_int8,
                         decompress_int8, warmup_cosine)
from repro.optim.adamw import global_norm, opt_state_specs


def test_data_determinism_and_resume():
    src = SyntheticLMSource(vocab=100, seq_len=8, global_batch=4, seed=7)
    b1 = src.batch_at(42)
    b2 = src.batch_at(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = src.batch_at(43)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_data_markov_structure_is_learnable():
    src = SyntheticLMSource(vocab=50, seq_len=16, global_batch=8, seed=0, branching=2)
    b = src.batch_at(0)
    # each token has at most `branching` successors
    succ = {}
    for row_t, row_l in zip(b["tokens"], b["labels"]):
        for t, l in zip(row_t, row_l):
            succ.setdefault(int(t), set()).add(int(l))
    assert max(len(v) for v in succ.values()) <= 2


def test_byte_file_source(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_bytes(b"hello world, this is a tiny corpus for byte-level lm tests" * 4)
    src = ByteFileSource(str(p), seq_len=8, global_batch=2, seed=0)
    b = src.batch_at(0)
    assert b["tokens"].shape == (2, 8) and b["tokens"].max() < 256


def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw w^2
        params, state, m = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1
    assert int(state["step"]) == 200


def test_grad_clip_applies():
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
    _, _, m = adamw_update(params, {"w": jnp.ones(3) * 1e6}, state, cfg)
    assert m["grad_norm"] > 1e5  # raw norm reported


@given(st.integers(0, 5000))
@settings(max_examples=20, deadline=None)
def test_warmup_cosine_bounds(step):
    v = float(warmup_cosine(step, warmup=100, total=5000, min_ratio=0.1))
    assert 0.0 <= v <= 1.0


def test_compress_int8_error_feedback_reduces_bias():
    rng = jax.random.PRNGKey(0)
    g = jax.random.normal(rng, (1000,)) * 0.01
    # without feedback: repeated quantization of same grad keeps same bias
    q, s, err = compress_int8(g)
    est1 = decompress_int8(q, s)
    # with feedback: two-step average approaches the true value
    q2, s2, err2 = compress_int8(g, err)
    est2 = (est1 + decompress_int8(q2, s2)) / 2
    bias1 = float(jnp.abs(est1 - g).mean())
    bias2 = float(jnp.abs(est2 - g).mean())
    assert bias2 < bias1


def test_opt_state_specs_zero1():
    from jax.sharding import PartitionSpec as P

    specs = {"w": P(None, "model"), "b": P(None)}
    shapes = {"w": jax.ShapeDtypeStruct((64, 8), jnp.float32),
              "b": jax.ShapeDtypeStruct((7,), jnp.float32)}
    out = opt_state_specs(specs, shapes, batch_axes=("data",), zero1=True,
                          axis_sizes={"data": 16})
    assert out["m"]["w"] == P(("data",), "model")   # 64 % 16 == 0 -> sharded
    assert out["m"]["b"] == P(None)                  # 7 % 16 != 0 -> replicated
    assert out["step"] == P()


def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    for s in (10, 20, 30):
        mgr.save(s, tree, metadata={"loss": s * 1.0})
    assert mgr.steps() == [20, 30]  # keep=2 garbage-collected step 10
    restored, step, meta = mgr.restore(tree)
    assert step == 30 and meta["loss"] == 30.0
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))


def test_checkpoint_corruption_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    tree = {"a": jnp.arange(4.0)}
    mgr.save(1, tree)
    mgr.save(2, tree)
    # corrupt the newest checkpoint's arrays
    (tmp_path / "ckpt_00000002" / "arrays.npz").write_bytes(b"garbage")
    restored, step, _ = mgr.restore(tree)
    assert step == 1


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.ones((32, 32))}
    mgr.save_async(5, tree)
    mgr.wait()
    assert mgr.latest_step() == 5
