import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every (architecture x applicable shape) cell:
  * build the step function + ShapeDtypeStruct inputs (launch/steps.py),
  * ``jax.jit(step, in_shardings=...).lower(...).compile()`` on the
    production mesh — (16, 16) single-pod and (2, 16, 16) multi-pod,
  * record memory_analysis / cost_analysis / collective-bytes (roofline).

Results are appended incrementally to results/dryrun.json so the sweep is
resumable.  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch yi_6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod-only-train]
"""

import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import extract
from repro.launch.shapes import SHAPES, applicable
from repro.launch.steps import build_cell

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results"


def _layer_period(cfg) -> int:
    if cfg.family == "transformer" and cfg.local_global_ratio:
        return cfg.local_global_ratio + 1
    if cfg.family == "rglru_hybrid":
        return len(cfg.block_pattern or ("rec", "rec", "attn"))
    return 1


def _extrapolation_depths(cfg) -> tuple[int, int] | None:
    """(L1, L2) reduced depths for the affine roofline pass, or None for
    full unroll.  Valid because the unrolled HLO cost is affine in the
    number of layer periods: cost(L) = base + (L/p) * period_cost."""
    p = _layer_period(cfg)
    L = cfg.n_layers
    if cfg.family in ("whisper",) or L % p:
        return None  # small or non-periodic tail (recurrentgemma 26 = 8*3+2)
    # Anchors: collective bytes extrapolate exactly (<0.1% error, validated
    # vs full unroll); FLOPs within ~2%; "bytes accessed" within ~15%
    # (fusion at the loss/embed boundary is not perfectly layer-affine).
    if cfg.family == "rwkv6":
        # each rwkv layer unrolls its 64-step chunk loop too: keep anchors
        # shallow or the autodiff'd HLO explodes
        l1, l2 = 1, 2
    else:
        l1, l2 = (p, 2 * p) if p >= 4 else (4 * p, 8 * p)
    if l2 >= L:
        return None
    return l1, l2


def _costs_of(rec: dict) -> dict:
    keys = ("hlo_flops", "hlo_bytes", "coll_bytes")
    out = {k: rec[k] for k in keys}
    out["coll_breakdown"] = dict(rec["coll_breakdown"])
    return out


def _affine(c1: dict, c2: dict, n1: float, n2: float, n: float) -> dict:
    def ext(a, b):
        per = (b - a) / (n2 - n1)
        return a + per * (n - n1)

    out = {k: ext(c1[k], c2[k]) for k in ("hlo_flops", "hlo_bytes", "coll_bytes")}
    keys = set(c1["coll_breakdown"]) | set(c2["coll_breakdown"])
    out["coll_breakdown"] = {
        k: ext(c1["coll_breakdown"].get(k, 0), c2["coll_breakdown"].get(k, 0))
        for k in keys}
    return out


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             variant: str = "baseline", cfg_override=None, verbose: bool = True,
             mesh=None, mesh_name: str | None = None, unroll: bool = True,
             build_opts: dict | None = None):
    """Two lowering modes (see EXPERIMENTS.md §Dry-run):

    * ``unroll=True`` — exact roofline accounting: XLA cost_analysis counts
      lax.scan bodies ONCE, so FLOPs/bytes/collectives are only correct when
      layer loops are unrolled.  Buffer-assignment "temp" memory is
      pessimistic in this mode (the scheduler keeps more unrolled buffers
      alive than the scanned program would).
    * ``unroll=False`` — the production lowering (scanned layers): proves
      shardability/compile and gives the realistic per-device memory.
    """
    cfg = cfg_override or get_config(arch)
    cfg = dataclasses.replace(cfg, scan_layers=not unroll)
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "mesh": "multi" if multi_pod else "single",
                "variant": variant, "status": "skipped", "reason": why}
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "2x16x16" if multi_pod else "16x16"
    mesh_name = mesh_name or "x".join(map(str, mesh.devices.shape))
    cell = SHAPES[shape]
    t0 = time.time()

    def compile_once(cfg_i):
        with jax.set_mesh(mesh):
            built = build_cell(cfg_i, shape, mesh, **(build_opts or {}))
            jitted = jax.jit(built["step_fn"],
                             in_shardings=built["in_shardings"],
                             out_shardings=built.get("out_shardings"),
                             donate_argnums=built["donate"])
            lowered = jitted.lower(*built["specs"])
            compiled = lowered.compile()
            hlo = compiled.as_text()
            roof = extract(arch, shape, mesh_name, mesh.devices.size, compiled,
                           hlo, cfg, built["kind"], cell.seq_len,
                           cell.global_batch)
            mem = compiled.memory_analysis()
        return roof, mem

    try:
        depths = _extrapolation_depths(cfg) if unroll else None
        if depths is None:
            roof, mem = compile_once(cfg)
            rec = roof.to_dict()
            rec["method"] = "unrolled-full" if unroll else "scanned"
        else:
            L1, L2 = depths
            roof1, _ = compile_once(dataclasses.replace(cfg, n_layers=L1))
            roof2, mem = compile_once(dataclasses.replace(cfg, n_layers=L2))
            ext = _affine(_costs_of(roof1.to_dict()), _costs_of(roof2.to_dict()),
                          L1, L2, cfg.n_layers)
            roof2.hlo_flops = ext["hlo_flops"]
            roof2.hlo_bytes = ext["hlo_bytes"]
            roof2.coll_bytes = ext["coll_bytes"]
            roof2.coll_breakdown = ext["coll_breakdown"]
            rec = roof2.to_dict()
            rec["method"] = f"unrolled-affine(L={L1},{L2})"
        rec.update({
            "variant": variant,
            "status": "ok",
            "compile_s": round(time.time() - t0, 1),
            "memory_analysis": {
                k: int(getattr(mem, k, 0)) for k in
                ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes")
            },
        })
        if verbose:
            print(f"[{mesh_name}] {arch} x {shape} ({variant}): OK "
                  f"({rec['compile_s']}s) bottleneck={rec['bottleneck']} "
                  f"frac={rec['roofline_fraction']:.3f} "
                  f"bytes/dev={rec['bytes_per_device']/2**30:.2f}GiB", flush=True)
        return rec
    except Exception as e:  # noqa: BLE001 — a failing cell is a recorded bug
        if verbose:
            print(f"[{mesh_name}] {arch} x {shape} ({variant}): FAIL {e}", flush=True)
            traceback.print_exc()
        return {"arch": arch, "shape": shape, "mesh": mesh_name,
                "variant": variant, "status": "fail", "error": str(e)[:2000],
                "compile_s": round(time.time() - t0, 1)}


def append_result(rec: dict, path: pathlib.Path | None = None):
    path = path or (RESULTS / "dryrun.json")
    path.parent.mkdir(parents=True, exist_ok=True)
    records = json.loads(path.read_text()) if path.exists() else []
    records = [r for r in records
               if not (r["arch"] == rec["arch"] and r["shape"] == rec["shape"]
                       and r["mesh"] == rec["mesh"]
                       and r.get("variant", "baseline") == rec.get("variant", "baseline"))]
    records.append(rec)
    path.write_text(json.dumps(records, indent=1))


def _done(records, arch, shape, mesh, variant) -> bool:
    return any(r["arch"] == arch and r["shape"] == shape and r["mesh"] == mesh
               and r.get("variant") == variant and r.get("status") != "fail"
               for r in records)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-multipod", action="store_true")
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out = pathlib.Path(args.out) if args.out else (RESULTS / "dryrun.json")
    existing = json.loads(out.read_text()) if (out.exists() and not args.no_resume) else []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    # passes per cell: scanned single-pod (compile/memory), unrolled
    # single-pod (roofline), scanned multi-pod (pod-axis proof)
    jobs = []
    for a in archs:
        for s in shapes:
            jobs.append((a, s, False, False, "compile-scan"))
            jobs.append((a, s, False, True, "baseline"))
            if not args.no_multipod:
                jobs.append((a, s, True, False, "compile-scan"))
    for a, s, mp, unroll, variant in jobs:
        mesh_name = "2x16x16" if mp else "16x16"
        if _done(existing, a, s, mesh_name, variant):
            print(f"[{mesh_name}] {a} x {s} ({variant}): cached, skip")
            continue
        rec = run_cell(a, s, multi_pod=mp, unroll=unroll, variant=variant)
        append_result(rec, out)


if __name__ == "__main__":
    main()
