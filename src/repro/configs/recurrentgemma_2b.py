"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680,
RG-LRU + local attention, pattern (rec, rec, attn), window 2048.
[arXiv:2402.19427]"""

from repro.configs._util import reduce_for_smoke
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="rglru_hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    attn_window=2048,
    block_pattern=("rec", "rec", "attn"),
    lru_width=2560,
    tie_embeddings=True,
)


def smoke_config():
    return reduce_for_smoke(CONFIG, n_heads=2, n_kv_heads=1, head_dim=32)
